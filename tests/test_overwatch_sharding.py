"""Sharded overwatch + coalesced watch delivery (the multi-layer refactor).

Covers the new guarantees: deterministic consistent-hash routing, per-shard op
accounting that sums to the front-end totals, single-shard semantic equivalence
with the sharded store, O(watchers) recovery storms under coalesced delivery,
bounded-staleness replica reads, batched admission, multiplexed DAG deltas and
zero-copy envelope accounting.
"""
from collections import Counter

import pytest

from repro.core.overwatch import (OverwatchService, ShardRouter,
                                  _route_segment)
from repro.core.plane import ManagementPlane, SimLocalPlane
from repro.core.transport import Envelope, Fabric, _payload_bytes
from repro.pipelines.taskdb import TaskDB


def _mk_service(num_shards=1, coalesce=False):
    fabric = Fabric()
    ow = OverwatchService(fabric, "m", num_shards=num_shards,
                          coalesce_watches=coalesce)
    return fabric, ow


def _storm_plane(n_clusters, **kwargs):
    plane = ManagementPlane(**kwargs)
    plane.add_cluster("master", is_master=True,
                      local_plane=SimLocalPlane(caps=("control",)))
    for i in range(n_clusters):
        plane.add_cluster(f"c{i}")
    return plane


# ------------------------------------------------------------------ routing
def test_router_deterministic_and_covering():
    r1 = ShardRouter(4)
    r2 = ShardRouter(4)
    segs = [f"seg-{i}" for i in range(256)]
    owners = [r1.shard_for_segment(s) for s in segs]
    # identical placement from independently constructed routers (clients can
    # route without asking the server)
    assert owners == [r2.shard_for_segment(s) for s in segs]
    # every shard owns a slice of the segment space
    assert set(owners) == {0, 1, 2, 3}
    # flat namespaces route by first segment: one segment, one shard
    assert r1.shard_for_key("/clusters/a") == \
        r1.shard_for_key("/clusters/zzz") == \
        r1.shard_for_segment("clusters")
    assert r1.shard_for_prefix("/clusters/") == \
        r1.shard_for_segment("clusters")
    # the per-entity /jobs namespace routes at depth 2: one job's keys share a
    # shard, different jobs spread across shards
    assert r1.shard_for_key("/jobs/a/status") == \
        r1.shard_for_key("/jobs/a/placement") == \
        r1.shard_for_segment("jobs/a")
    assert len({r1.shard_for_key(f"/jobs/j{i}/status")
                for i in range(64)}) == 4
    # a prefix pinning a full routing segment resolves to that shard;
    # shorter prefixes fan out
    assert r1.shard_for_prefix("/jobs/a/") == r1.shard_for_segment("jobs/a")
    assert r1.shard_for_prefix("/jobs/") is None
    assert r1.shard_for_prefix("/jo") is None
    assert r1.shard_for_prefix("") is None
    # structureless keys still route deterministically
    assert _route_segment("/cfg") == "cfg"
    assert r1.shard_for_key("/cfg") == r1.shard_for_segment("cfg")


def test_sharded_semantics_match_single_shard():
    """The same mixed workload on 1 and 4 shards yields identical reads."""
    results = []
    for shards in (1, 4):
        _, ow = _mk_service(num_shards=shards)
        revs = []
        for i in range(40):
            revs.append(ow.handle({"op": "put", "key": f"/p{i % 5}/k{i}",
                                   "value": i})["revision"])
        ow.handle({"op": "delete", "key": "/p0/k0"})
        ow.handle({"op": "cas", "key": "/p1/k1", "value": "swapped",
                   "expect_revision": revs[1]})
        assert revs == sorted(revs) and len(set(revs)) == len(revs)
        reads = {
            "get": [ow.handle({"op": "get", "key": f"/p{i % 5}/k{i}"})["value"]
                    for i in range(40)],
            "range_one": ow.handle({"op": "range", "prefix": "/p2/"})["items"],
            "range_fan": list(ow.handle({"op": "range",
                                         "prefix": ""})["items"].items()),
        }
        results.append(reads)
    assert results[0] == results[1]


def test_per_shard_op_counters_sum_to_front_end_totals():
    _, ow = _mk_service(num_shards=4)
    for i in range(60):
        ow.handle({"op": "put", "key": f"/pre{i % 7}/k{i}", "value": i})
        ow.handle({"op": "get", "key": f"/pre{i % 7}/k{i}"})
    for i in range(0, 60, 3):
        ow.handle({"op": "delete", "key": f"/pre{i % 7}/k{i}"})
    ow.handle({"op": "range", "prefix": "/pre1/"})     # single-shard range
    shard_total = Counter()
    for shard in ow.shards:
        shard_total += shard.op_counts
    for op in ("put", "get", "delete", "range"):
        assert shard_total[op] == ow.op_counts[op]
    # work actually spread over more than one shard
    assert sum(1 for s in ow.shards if s.op_counts["put"]) > 1


def test_per_shard_fabric_endpoints_and_client_routing():
    """Master-local shard-aware clients hit shard endpoints directly; the
    results match front-end routing."""
    plane = ManagementPlane(ow_shards=4)
    plane.add_cluster("master", is_master=True)
    plane.add_cluster("onprem-a")
    ow = plane.overwatch
    master_client = plane.agents["master"].ow
    remote_client = plane.agents["onprem-a"].ow
    assert master_client.shard_addrs is not None
    assert remote_client.shard_vias is not None and \
        len(remote_client.shard_vias) == 4
    master_client.put("/bench/k", {"v": 1})
    assert remote_client.get("/bench/k") == {"v": 1}
    owning = ow.router.shard_for_key("/bench/k")
    assert ow.shards[owning].op_counts["put"] >= 1
    # shard_map reports one endpoint per shard
    m = ow.handle({"op": "shard_map"})
    assert m["num_shards"] == 4 and len(m["ports"]) == 4


# --------------------------------------------------------- coalesced delivery
def test_batch_watcher_sync_mode_singletons():
    _, ow = _mk_service()
    events = []
    batches = []
    ow.watch("/x/", lambda e, k, v, r: events.append((e, k)))
    ow.watch_batch("/x/", batches.append)
    ow.handle({"op": "put", "key": "/x/a", "value": 1})
    ow.handle({"op": "delete", "key": "/x/a"})
    assert events == [("put", "/x/a"), ("delete", "/x/a")]
    assert [len(b) for b in batches] == [1, 1]          # synchronous singletons
    assert batches[1][0][0] == "delete"


def test_coalesced_delivery_flushes_in_revision_order():
    _, ow = _mk_service(num_shards=4, coalesce=True)
    batches = []
    ow.watch_batch("", batches.append)                  # catch-all, all shards
    for i in range(10):
        ow.handle({"op": "put", "key": f"/p{i % 3}/k{i}", "value": i})
    assert batches == []                                # nothing until flush
    ow.flush_watches()
    assert len(batches) == 1                            # one callback, one batch
    revs = [r for _, _, _, r in batches[0]]
    assert len(batches[0]) == 10 and revs == sorted(revs)
    ow.flush_watches()                                  # idempotent when drained
    assert len(batches) == 1


def test_recovery_storm_is_o_watchers_not_o_jobs():
    """5k jobs on a dying cluster: coalesced delivery recovers them all with
    a handful of batched callbacks instead of one per mutation."""
    n_jobs = 5000
    plane = _storm_plane(4, ow_shards=2, coalesce_watches=True)
    for j in range(n_jobs):
        plane.overwatch.handle(
            {"op": "put", "key": f"/jobs/pre-{j}/placement",
             "value": {"cluster": "c0",
                       "job": {"job_id": f"pre-{j}", "kind": "sim",
                               "steps": 10, "tags": {}, "payload": {}},
                       "clock": 0.0}})
        plane.overwatch.handle(
            {"op": "put", "key": f"/jobs/pre-{j}/status",
             "value": {"cluster": "c0", "status": "running",
                       "progress": 1.0, "rate": 1.0, "clock": 0.0}})
    plane.tick(n=2)
    before = Counter(plane.overwatch.watch_stats)
    plane.fabric.partition_cluster("c0")
    plane.tick(n=8)                          # lease expiry -> recovery storm
    delta = Counter(plane.overwatch.watch_stats) - before
    # O(mutations) events flowed through...
    assert delta["events"] > 2 * n_jobs
    # ...in O(watchers) callback invocations (3 dispatcher watchers x a few
    # flush rounds), nowhere near O(jobs)
    assert delta["callbacks"] < 100
    # and every job really moved off the dead cluster
    for j in range(0, n_jobs, 500):
        placed = plane.overwatch.handle(
            {"op": "get", "key": f"/jobs/pre-{j}/placement"})["value"]
        assert placed["cluster"] != "c0"


def test_job_placed_same_round_as_cluster_death_is_recovered():
    """A placement event and the placed-on cluster's lease tombstone landing
    in the SAME flush round must still recover the job: the dispatcher's job
    view ingests its slice of the round before the cluster tombstone's
    recovery side effect reads it."""
    plane = _storm_plane(3, ow_shards=2, coalesce_watches=True)
    plane.tick(n=2)
    jid = plane.submit_job("sim", steps=50, tags={"requires": ("cpu",)})
    placed = plane.overwatch.handle(
        {"op": "get", "key": f"/jobs/{jid}/placement"})["value"]["cluster"]
    # the placement watch event is still pending (no barrier since submit);
    # partition the placed-on cluster and advance the raw fabric clock so its
    # lease expires mid-tick — heartbeat handles sweep the lease but nothing
    # flushes until the explicit sweep below, putting the placement put and
    # the cluster tombstone in one flush round
    plane.fabric.partition_cluster(placed)
    for _ in range(6):
        plane.fabric.tick(1.0)
    plane.overwatch.sweep()
    after = plane.overwatch.handle(
        {"op": "get", "key": f"/jobs/{jid}/placement"})["value"]
    assert after["cluster"] != placed       # recovered, not stranded


def test_submit_many_survives_mid_batch_cluster_death():
    """A lease already due for expiry when the batch starts is swept by the
    batch's own placement puts; the cached min-load block must notice the
    vanished cluster and re-probe instead of dispatching into it."""
    import heapq
    plane = _storm_plane(3)
    # make c1's lease due NOW without any handle() call sweeping it yet: the
    # batch's first placement put will fire the sweep mid-batch, after the
    # min-load block has been computed with c1 still in it
    lid = plane.agents["c1"].lease
    lease = plane.overwatch._leases[lid]
    lease.expires_at = plane.fabric.clock
    heapq.heappush(plane.overwatch._expiry_heap, (lease.expires_at, lid))
    jids = plane.submit_jobs([{"kind": "sim", "steps": 2} for _ in range(8)])
    assert "c1" not in plane.dispatcher.clusters()       # swept mid-batch
    # round-robin started at c0, so c1's block slot came up after the sweep:
    # every job must have landed on a still-registered cluster
    for j in jids:
        placed = plane.overwatch.handle(
            {"op": "get", "key": f"/jobs/{j}/placement"})["value"]
        assert placed["cluster"] != "c1"


def test_put_with_dead_lease_leaves_no_trace():
    """A put rejected for an unknown/expired lease must not mutate the store:
    no key, no revision bump, no watch event (store/views stay convergent)."""
    _, ow = _mk_service()
    events = []
    ow.watch("/svc/", lambda *a: events.append(a))
    rev_before = ow._rev
    r = ow.handle({"op": "put", "key": "/svc/ghost", "value": 1, "lease": 999})
    assert not r["ok"] and "lease" in r["error"]
    assert ow.handle({"op": "get", "key": "/svc/ghost"})["value"] is None
    assert ow._rev == rev_before and events == []


def test_coalesced_plane_runs_jobs_end_to_end():
    plane = _storm_plane(3, ow_shards=4, coalesce_watches=True)
    jids = [plane.submit_job("sim", steps=5, tags={"requires": ("cpu",)})
            for _ in range(6)]
    assert plane.run_until_done(jids, max_ticks=40)
    for j in jids:
        assert plane.job_status(j)["status"] == "done"


def test_submit_many_retries_on_mid_batch_delivery_failure():
    """Coalesced mode: a cluster that dies mid-batch is only a pending
    tombstone, so the block's membership check cannot see it — the failed
    dispatch itself must trigger a barrier + re-placement, and the rest of
    the batch must still be admitted."""
    import heapq
    plane = _storm_plane(3, ow_shards=2, coalesce_watches=True)
    plane.tick(n=2)
    # c1 is partitioned AND its lease is due: the first placement put sweeps
    # the lease (tombstone pending, views unchanged), and any dispatch that
    # round-robins onto c1 raises DeliveryError
    lid = plane.agents["c1"].lease
    lease = plane.overwatch._leases[lid]
    lease.expires_at = plane.fabric.clock
    heapq.heappush(plane.overwatch._expiry_heap, (lease.expires_at, lid))
    plane.fabric.partition_cluster("c1")
    jids = plane.submit_jobs([{"kind": "sim", "steps": 2} for _ in range(8)])
    assert len(jids) == 8                    # whole batch admitted
    for j in jids:
        placed = plane.overwatch.handle(
            {"op": "get", "key": f"/jobs/{j}/placement"})["value"]
        assert placed["cluster"] != "c1"


def test_raising_watcher_does_not_lose_round_events():
    """A callback that raises during a coalesced flush must not drop the
    round's events for other watchers: everyone else still gets their batch
    and the exception surfaces at the barrier."""
    _, ow = _mk_service(num_shards=2, coalesce=True)
    got = []
    ow.watch("/clusters/", lambda *a: (_ for _ in ()).throw(
        RuntimeError("subscriber crashed")))
    ow.watch_batch("/jobs/", got.extend)
    ow.handle({"op": "put", "key": "/jobs/j1/placement", "value": {"c": 1}})
    ow.handle({"op": "put", "key": "/clusters/c0", "value": {}})
    with pytest.raises(RuntimeError, match="subscriber crashed"):
        ow.flush_watches()
    assert [k for _, k, _, _ in got] == ["/jobs/j1/placement"]
    # the dropped-nothing invariant holds on the next round too
    ow.handle({"op": "put", "key": "/jobs/j2/placement", "value": {"c": 2}})
    ow.flush_watches()
    assert [k for _, k, _, _ in got] == ["/jobs/j1/placement",
                                        "/jobs/j2/placement"]


# ------------------------------------------------------------- read replica
def test_range_stale_bounded_staleness():
    fabric, ow = _mk_service(num_shards=2, coalesce=True)
    ow.handle({"op": "put", "key": "/telemetry/a", "value": 1})
    ow.flush_watches()
    # first stale read materializes the replica (fresh at that instant)
    r = ow.handle({"op": "range_stale", "prefix": "/telemetry/",
                   "max_lag": 10.0})
    assert r["items"] == {"/telemetry/a": 1} and r["lag"] == 0.0
    # mutate without flushing, then advance the clock past the pending write
    ow.handle({"op": "put", "key": "/telemetry/b", "value": 2})
    fabric.tick(5.0)
    # a tolerant reader is served the stale snapshot at a bounded, reported lag
    r = ow.handle({"op": "range_stale", "prefix": "/telemetry/",
                   "max_lag": 10.0})
    assert r["items"] == {"/telemetry/a": 1}
    assert 0.0 < r["lag"] <= 10.0
    # the linearizable primary path sees the new key the whole time
    assert ow.handle({"op": "range", "prefix": "/telemetry/"})["items"] == \
        {"/telemetry/a": 1, "/telemetry/b": 2}
    # a strict reader forces catch-up: lag above max_lag triggers a flush
    r = ow.handle({"op": "range_stale", "prefix": "/telemetry/",
                   "max_lag": 1.0})
    assert r["items"] == {"/telemetry/a": 1, "/telemetry/b": 2}
    assert r["lag"] == 0.0
    # replica tracks deletes too (tick so the tombstone's lag is measurable)
    ow.handle({"op": "delete", "key": "/telemetry/a"})
    fabric.tick(1.0)
    r = ow.handle({"op": "range_stale", "prefix": "/telemetry/",
                   "max_lag": 0.5})
    assert r["items"] == {"/telemetry/b": 2}


def test_range_stale_inside_flush_falls_back_to_primary():
    """A strict range_stale issued from inside a flush (where the nested
    catch-up barrier is a no-op) must not silently exceed max_lag — it serves
    the linearizable primary instead."""
    fabric, ow = _mk_service(num_shards=2, coalesce=True)
    ow.handle({"op": "put", "key": "/telemetry/a", "value": 1})
    ow.flush_watches()
    ow.handle({"op": "range_stale", "prefix": "/telemetry/",
               "max_lag": 10.0})            # materialize the replica
    seen = []

    def nosy_watcher(events):
        ow.handle({"op": "put", "key": "/telemetry/late", "value": 9})
        fabric.clock += 5.0                 # the new put is now 5 units stale
        seen.append(ow.handle({"op": "range_stale", "prefix": "/telemetry/",
                               "max_lag": 1.0}))

    ow.watch_batch("/trigger/", nosy_watcher)
    ow.handle({"op": "put", "key": "/trigger/t", "value": 0})
    ow.flush_watches()
    (r,) = seen
    assert r["items"] == {"/telemetry/a": 1, "/telemetry/late": 9}  # primary
    assert r["lag"] <= 1.0


def test_range_stale_via_client():
    plane = ManagementPlane(ow_shards=2, coalesce_watches=True)
    plane.add_cluster("master", is_master=True)
    plane.add_cluster("onprem-a")
    plane.tick(n=2)
    items = plane.agents["onprem-a"].ow.range_stale("/clusters/", max_lag=5.0)
    assert set(items) == {"/clusters/master", "/clusters/onprem-a"}


# --------------------------------------------------------- batched admission
def test_submit_many_places_and_balances():
    plane = _storm_plane(4)
    jids = plane.submit_jobs([{"kind": "sim", "steps": 5,
                               "tags": {"requires": ("cpu",)}}
                              for _ in range(8)])
    assert len(jids) == len(set(jids)) == 8
    placements = Counter()
    for j in jids:
        placed = plane.overwatch.handle(
            {"op": "get", "key": f"/jobs/{j}/placement"})["value"]
        placements[placed["cluster"]] += 1
    # round-robin over the min-load block: all four cpu clusters used evenly
    assert placements == Counter({f"c{i}": 2 for i in range(4)})
    assert plane.run_until_done(jids, max_ticks=40)


def test_submit_many_amortizes_admission():
    """Batched admission must not re-probe per job: the overwatch op profile of
    a 16-job batch equals 16 single submits (placement+status puts only), and
    unconstrained placement does zero additional reads."""
    plane = _storm_plane(3)
    before = Counter(plane.overwatch.op_counts)
    plane.submit_jobs([{"kind": "sim", "steps": 1} for _ in range(16)])
    delta = Counter(plane.overwatch.op_counts) - before
    assert delta["range"] == 0 and delta["get"] == 0
    assert delta["put"] == 2 * 16            # placement + status per job


def test_submit_many_respects_rules_and_capabilities():
    from repro.core.dispatcher import RoutingRule
    plane = _storm_plane(3)
    plane.add_routing_rule(RoutingRule(
        name="pin", match=lambda j: j.get("tags", {}).get("pii"),
        clusters=["c1"]))
    jids = plane.submit_jobs([
        {"kind": "sim", "steps": 2, "tags": {"pii": True}},
        {"kind": "sim", "steps": 2, "tags": {"requires": ("cpu",)}},
        {"kind": "sim", "steps": 2},
    ])
    placed = [plane.overwatch.handle(
        {"op": "get", "key": f"/jobs/{j}/placement"})["value"]["cluster"]
        for j in jids]
    assert placed[0] == "c1"
    assert placed[1].startswith("c")


# --------------------------------------------------------- dag_delta_many
def test_taskdb_dag_delta_many_multiplexes():
    db = TaskDB()
    for dag in ("d1", "d2", "d3"):
        db.handle({"op": "upsert", "dag": dag, "task": "a", "try": 1,
                   "status": "queued", "clock": 0.0})
    r = db.handle({"op": "dag_delta_many",
                   "dags": {"d1": 0, "d2": 0, "d3": 0, "ghost": 0}})
    assert set(r["deltas"]) == {"d1", "d2", "d3"}       # ghost: no delta entry
    cur = r["cursor"]
    db.handle({"op": "upsert", "dag": "d2", "task": "a", "try": 1,
               "status": "success", "clock": 1.0})
    r2 = db.handle({"op": "dag_delta_many",
                    "dags": {"d1": cur, "d2": cur, "d3": cur}})
    assert set(r2["deltas"]) == {"d2"}                  # only the dirty DAG
    assert r2["deltas"]["d2"]["a"]["status"] == "success"
    # agrees with the single-DAG op
    single = db.handle({"op": "dag_delta", "dag": "d2", "since": cur})
    assert single["tasks"] == r2["deltas"]["d2"]
    # quiescent: empty deltas
    r3 = db.handle({"op": "dag_delta_many", "dags": {"d2": r2["cursor"]}})
    assert r3["deltas"] == {}


# --------------------------------------------------------- zero-copy envelopes
def test_envelope_accounting_matches_and_caches():
    plain = {"op": "put", "key": "/jobs/j/status",
             "value": {"cluster": "c0", "status": "running",
                       "progress": 1.0, "rate": 1.0, "clock": 0.0}}
    env = Envelope(plain)
    assert _payload_bytes(env) == _payload_bytes(plain)  # same ledger bytes
    # cached: mutating after the first measurement is not re-walked
    first = env.nbytes
    env["value"]["extra"] = "x" * 100
    assert _payload_bytes(env) == first
    # construction-time sizes are honored verbatim
    assert _payload_bytes(Envelope({"a": 1}, nbytes=123)) == 123


def test_envelope_rides_the_fabric_once_sized():
    fabric = Fabric()
    fabric.register_handler("c", ("ip", 1), lambda p: {"ok": True})
    env = Envelope({"op": "noop", "data": [1, 2, 3]})
    fabric.send("c", "pod", "c", ("ip", 1), env)
    n = fabric.local_bytes["c"]
    # purely-local round trip: the request is charged (sized once via the
    # Envelope cache), the response is never even walked
    assert n == _payload_bytes(dict(env))
    fabric.send("c", "pod", "c", ("ip", 1), env)
    assert fabric.local_bytes["c"] == 2 * n
