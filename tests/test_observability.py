"""Flight recorder: trace propagation over the fabric, span-exactness across
broker shards, crash/partition truncation (never leaks, never double-closes),
byte-identity when sampling is off, the unified metrics registry, and the
zero-cross-boundary /metrics/ export over the replica delta feed."""
from collections import Counter

import pytest

from repro.core.durability import LogStore
from repro.core.faults import ChaosHarness, FaultPlan, FaultPoint
from repro.core.plane import ManagementPlane, SimLocalPlane
from repro.observability import (MetricsRegistry, Tracer, critical_path,
                                 format_trace_report)
from repro.pipelines import DAG, HybridComposer, Task
from repro.runtime.telemetry import MetricsLog

SPAN_NAMES = {"task", "schedule", "queue", "execute", "commit"}


# ------------------------------------------------------------------ registry
def test_registry_counters_gauges_histograms_and_sources():
    reg = MetricsRegistry("master")
    reg.inc("fabric.sends")
    reg.inc("fabric.sends", 4)
    reg.set_gauge("pool.size", 3)
    for ms in (1, 2, 3, 4, 100):
        reg.observe("svc.latency", ms / 1000.0)
    reg.register_source("broker.b0", lambda: {"pushes": 7})
    snap = reg.snapshot()
    assert snap["fabric.sends"] == 5
    assert snap["pool.size"] == 3
    assert snap["broker.b0.pushes"] == 7
    assert snap["svc.latency.count"] == 5
    # p50 lands in the low-millisecond buckets, p99 must see the outlier
    assert snap["svc.latency.p50"] <= 0.01
    assert snap["svc.latency.p99"] >= 0.05
    assert snap["svc.latency.max"] == pytest.approx(0.1)
    # re-registering a prefix overwrites (recovery re-registers freely)
    reg.register_source("broker.b0", lambda: {"pushes": 9})
    assert reg.snapshot()["broker.b0.pushes"] == 9
    # a failing source is skipped and counted, never raises out of snapshot
    reg.register_source("bad", lambda: 1 / 0)
    snap = reg.snapshot()
    assert not any(k.startswith("bad") for k in snap)
    assert reg.source_errors["bad"] == 1
    assert "svc" in reg.sections() and "broker" in reg.sections()


def test_histogram_empty_and_single_value():
    reg = MetricsRegistry()
    assert reg.snapshot() == {}
    reg.observe("h", 0.5)
    s = reg.snapshot()
    assert s["h.count"] == 1
    assert s["h.min"] == s["h.max"] == pytest.approx(0.5)
    # quantiles are clamped to the observed range
    assert s["h.p50"] == pytest.approx(0.5)
    assert s["h.p99"] == pytest.approx(0.5)


def test_metricslog_ring_is_bounded():
    log = MetricsLog(capacity=16)
    for i in range(50):
        log.log(i, {"loss": float(i)})
    assert len(log.rows) == 16
    assert [r["step"] for r in log.rows] == list(range(34, 50))
    assert log.series("loss") == [float(i) for i in range(34, 50)]


# ---------------------------------------------------------- trace over fabric
def _traced_plane(**kw):
    plane = ManagementPlane(trace_sample=1.0, **kw)
    plane.add_cluster("master", is_master=True,
                      local_plane=SimLocalPlane(caps=("control",)))
    plane.add_cluster("onprem-a", local_plane=SimLocalPlane(caps=("cpu",)))
    plane.add_cluster("onprem-b", local_plane=SimLocalPlane(caps=("cpu",)))
    return plane


def test_trace_ctx_crosses_gateway_relay():
    """A dispatch to a remote cluster carries its trace ctx across the
    fabric hop: the receiving agent's accept span joins the SAME trace as
    the dispatcher's job root, parented under the dispatch span."""
    plane = _traced_plane()
    jid = plane.submit_job("sim", steps=5, tags={"requires": ("cpu",)})
    assert plane.run_until_done([jid], max_ticks=100)
    tr = plane.tracer
    spans = tr.trace(f"job/{jid}")
    by_name = {s.name: s for s in spans}
    assert {"job", "dispatch", "accept"} <= set(by_name)
    # one shared trace_id end to end
    assert len({s.trace_id for s in spans}) == 1
    # accept ran on the remote agent, parented under the dispatch hop
    assert by_name["accept"].parent_id == by_name["dispatch"].span_id
    assert by_name["accept"].attrs["cluster"] != "master"
    assert by_name["dispatch"].parent_id == by_name["job"].span_id
    assert not by_name["job"].open and by_name["job"].status == "ok"
    assert tr.accounting_ok() and tr.open_count == 0


def _pipeline(n_tasks=12, trace_sample=0.0, tracer=None, broker_shards=1,
              durability=None, plane=None):
    if plane is None:
        plane = ManagementPlane(durability=durability)
        plane.add_cluster("master", is_master=True,
                          local_plane=SimLocalPlane(caps=("control",)))
        plane.add_cluster("onprem-a",
                          local_plane=SimLocalPlane(caps=("cpu",)))
        plane.add_cluster("cloud-a",
                          local_plane=SimLocalPlane(caps=("cpu",)))
    comp = HybridComposer(plane,
                          workers={"onprem-a": ["w0"], "cloud-a": ["w1"]},
                          broker_shards=broker_shards,
                          durability=durability,
                          trace_sample=trace_sample, tracer=tracer)
    comp.add_dag(DAG("d", [Task(f"t{i}", kind="python")
                           for i in range(n_tasks)]))
    return plane, comp


def test_every_task_gets_exactly_five_spans_across_broker_shards():
    """Sharded brokers fan the queue spans out across shard WALs; each task
    still gets exactly one {task, schedule, queue, execute, commit} set —
    no lost spans, no duplicates, nothing left open."""
    plane, comp = _pipeline(n_tasks=12, trace_sample=1.0, broker_shards=3)
    assert comp.run_dag("d", max_ticks=200)
    tr = comp.tracer
    for i in range(12):
        spans = tr.trace(f"d/t{i}")
        names = sorted(s.name for s in spans)
        assert names == sorted(SPAN_NAMES), f"t{i}: {names}"
        assert all(not s.open and s.status == "ok" for s in spans)
    assert tr.open_count == 0 and tr.accounting_ok()
    assert tr.stats["opened"] == 12 * 5
    assert tr.stats["double_close"] == 0
    # critical path decomposes the root into its lifecycle segments
    cp = critical_path(tr, "d/t0")
    assert cp["status"] == "ok" and cp["total"] >= 0
    assert {"schedule", "queue", "execute", "commit"} <= set(cp["segments"])
    assert cp["dominant"] in SPAN_NAMES - {"task"}
    assert format_trace_report(tr)           # renders without blowing up


def test_sampling_off_is_byte_identical_and_spanless():
    """sample=0.0 attaches no trace keys: every fabric byte/op counter is
    identical to a tracer-less run, and zero spans are recorded."""
    results = []
    for tracer in (None, Tracer(sample=0.0)):
        plane, comp = _pipeline(n_tasks=10, tracer=tracer)
        assert comp.run_dag("d", max_ticks=200)
        results.append(dict(plane.fabric.stats))
    assert results[0] == results[1]
    plane, comp = _pipeline(n_tasks=10, tracer=Tracer(sample=0.0))
    assert comp.run_dag("d", max_ticks=200)
    assert comp.tracer.stats["opened"] == 0
    assert not comp.tracer.spans


def test_crash_restart_truncates_spans_never_leaks():
    """Spans open at the moment of a master crash (staged schedules, queued
    tasks) are TRUNCATED by recovery, then re-opened by WAL replay; the
    accounting identity opened == closed + truncated + open holds with zero
    double-closes and nothing left open at the end."""
    dur = LogStore()
    plane, comp = _pipeline(n_tasks=60, trace_sample=1.0, broker_shards=2,
                            durability=dur)
    h = ChaosHarness(plane, comp, FaultPlan.crash_at_ops(10, 20),
                     downtime_ticks=2)
    assert h.run(lambda: comp.scheduler.dag_success("d"), max_ticks=400)
    assert h.crashes == 2
    tr = comp.tracer
    assert tr.accounting_ok()
    assert tr.stats["double_close"] == 0
    assert tr.open_count == 0
    # roots survive the crash: every task trace still closes "ok"
    for i in range(60):
        root = [s for s in tr.trace(f"d/t{i}") if s.name == "task"]
        assert len(root) == 1 and root[0].status == "ok"


def test_partition_heal_keeps_spans_balanced():
    plane, comp = _pipeline(n_tasks=40, trace_sample=1.0)
    plan = FaultPlan([
        FaultPoint(action="partition", cluster="cloud-a", at_op=4),
        FaultPoint(action="heal", cluster="cloud-a", at_op=14),
    ])
    h = ChaosHarness(plane, comp, plan)
    assert h.run(lambda: comp.scheduler.dag_success("d"), max_ticks=400)
    tr = comp.tracer
    assert tr.open_count == 0 and tr.accounting_ok()
    assert tr.stats["double_close"] == 0


# -------------------------------------------------------------- /metrics/ ex
def test_metrics_export_rides_replica_feed_zero_cross_reads():
    """Agents snapshot their registries under /metrics/<cluster>/... which
    the PR 7 shipper fans out; any cluster then reads the whole fleet's
    metrics via range_stale at zero cross-boundary cost."""
    plane = ManagementPlane(coalesce_watches=True, replica_fanout=True,
                            trace_sample=1.0, metrics_every=0.5)
    plane.add_cluster("master", is_master=True,
                      local_plane=SimLocalPlane(caps=("control",)))
    plane.add_cluster("onprem-a", local_plane=SimLocalPlane(caps=("cpu",)))
    plane.add_cluster("cloud-a", local_plane=SimLocalPlane(caps=("cpu",)))
    comp = HybridComposer(plane,
                          workers={"onprem-a": ["w0"], "cloud-a": ["w1"]},
                          worker_queues={"w0": ("default",),
                                         "w1": ("default",)})
    comp.add_dag(DAG("d", [Task(f"t{i}", kind="python")
                           for i in range(16)]))
    assert comp.run_dag("d", max_ticks=200)
    plane.tick(n=3)                         # let publication + ship settle
    agent = plane.agents["onprem-a"]
    items = dict(agent.ow.range_stale("/metrics/", max_lag=10.0))
    assert any(k.startswith("/metrics/master/fabric") for k in items)
    # per-queue-family service time, recorded at ack time on the worker
    svc = {k: v for k, v in items.items()
           if "pipeline" in k and any("service_time" in m for m in v)}
    assert svc, f"no service-time section in {sorted(items)}"
    sect = next(iter(svc.values()))
    assert sect["service_time.default.count"] >= 1
    assert "service_time.default.p50" in sect
    assert "service_time.default.p99" in sect
    # satellite (b): registry fabric section agrees with the live counters
    fab = items["/metrics/master/fabric"]
    assert 0 < fab["cross_cluster_bytes"] <= \
        plane.fabric.cross_cluster_bytes()
    # replica watch counters surface through the same registry
    rep_keys = [k for k in items if "/replica" in k]
    assert rep_keys, f"no replica section in {sorted(items)}"
    # the read itself crossed no boundary: repeating it moves zero bytes
    cross = plane.fabric.cross_cluster_bytes()
    again = dict(agent.ow.range_stale("/metrics/", max_lag=10.0))
    assert plane.fabric.cross_cluster_bytes() == cross
    assert again.keys() == items.keys()


def test_metrics_export_off_by_default_ships_nothing():
    plane = ManagementPlane(coalesce_watches=True, replica_fanout=True)
    plane.add_cluster("master", is_master=True,
                      local_plane=SimLocalPlane(caps=("control",)))
    plane.add_cluster("onprem-a", local_plane=SimLocalPlane(caps=("cpu",)))
    plane.tick(n=5)
    agent = plane.agents["onprem-a"]
    assert not agent.ow.range_stale("/metrics/", max_lag=10.0)


def test_trace_off_records_nothing_on_the_plane():
    plane = ManagementPlane()
    plane.add_cluster("master", is_master=True)
    assert plane.tracer is None
    assert plane.agents["master"].tracer is None
