"""Data-plane throughput overhaul: batched broker protocol, heap-based leases,
worker commit pipelining, delta-driven scheduler, depth-aware placement.

Like test_control_plane_perf.py these pin the SHAPE of the cost (op counts,
heap behavior) plus the semantic guarantees (redelivery order, try metadata,
sync-vs-batched equivalence), not wall-time.
"""
from collections import Counter

import pytest

from repro.core.plane import ManagementPlane, SimLocalPlane
from repro.pipelines import DAG, Task, HybridComposer
from repro.pipelines.broker import Broker
from repro.pipelines.scheduler import Scheduler
from repro.pipelines.taskdb import TaskDB
from repro.pipelines.worker import PipelineWorker


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


# ------------------------------------------------------------------ broker
def test_pull_many_partial_fill_and_empty():
    b = Broker()
    b.handle({"op": "push_many", "queue": "q",
              "msgs": [{"i": i} for i in range(3)]})
    got = b.handle({"op": "pull_many", "queue": "q", "max_n": 10})
    assert got["msgs"] == [{"i": 0}, {"i": 1}, {"i": 2}]   # partial fill, FIFO
    assert len(got["tags"]) == 3
    again = b.handle({"op": "pull_many", "queue": "q", "max_n": 10})
    assert again["msgs"] == [] and again["tags"] == []
    assert b.handle({"op": "pull_many", "queue": "missing", "max_n": 4}
                    )["msgs"] == []


def test_ack_many_is_idempotent():
    b = Broker()
    b.handle({"op": "push_many", "queue": "q", "msgs": [{"i": 0}, {"i": 1}]})
    tags = b.handle({"op": "pull_many", "queue": "q", "max_n": 2})["tags"]
    assert b.handle({"op": "ack_many", "tags": tags})["acked"] == 2
    # double-ack + unknown tags: skipped, never raises, counts stay sane
    assert b.handle({"op": "ack_many", "tags": tags + [999]})["acked"] == 0
    d = b.handle({"op": "depth", "queue": "q"})
    assert (d["ready"], d["inflight"]) == (0, 0)


def test_depth_reports_ready_and_inflight():
    b = Broker()
    b.handle({"op": "push_many", "queue": "q",
              "msgs": [{"i": i} for i in range(5)]})
    b.handle({"op": "pull_many", "queue": "q", "max_n": 2})
    d = b.handle({"op": "depth", "queue": "q"})
    assert d["ready"] == 3 and d["inflight"] == 2
    assert d["depth"] == 3                   # legacy field = ready
    many = b.handle({"op": "depth_many"})["depths"]
    assert many == {"q": {"ready": 3, "inflight": 2}}
    some = b.handle({"op": "depth_many", "queues": ["q", "empty"]})["depths"]
    assert some["empty"] == {"ready": 0, "inflight": 0}


def test_expiry_heap_ordering_and_lazy_deletion():
    clock = FakeClock()
    b = Broker(clock_fn=clock, lease=10.0)
    b.handle({"op": "push_many", "queue": "q", "msgs": [{"m": "a"}, {"m": "b"}]})
    ta = b.handle({"op": "pull", "queue": "q"})["tag"]
    clock.t = 4.0
    b.handle({"op": "pull", "queue": "q"})           # tag b, expires later
    b.handle({"op": "ack", "tag": ta})               # a acked -> heap entry stale
    clock.t = 12.0                                   # a's entry due, b live
    b.stats.clear()
    b.handle({"op": "depth", "queue": "q"})
    assert b.stats["expire_scanned"] == 1            # popped the stale entry
    assert b.stats["redelivered"] == 0               # ...but redelivered nothing
    clock.t = 15.0                                   # now b's lease lapses too
    got = b.handle({"op": "pull", "queue": "q"})
    assert got["msg"] == {"m": "b"}                  # redelivered, a stays acked
    assert not b.inflight or got["tag"] in b.inflight


def test_expired_redelivery_is_fifo_by_default():
    clock = FakeClock()
    b = Broker(clock_fn=clock, lease=5.0)
    b.handle({"op": "push_many", "queue": "q", "msgs": [{"m": "a"}, {"m": "b"}]})
    b.handle({"op": "pull_many", "queue": "q", "max_n": 2})   # both leased
    b.handle({"op": "push", "queue": "q", "msg": {"m": "c"}})  # head waiter
    clock.t = 6.0
    b.handle({"op": "depth", "queue": "q"})          # trigger expiry sweep
    order = [m["m"] for m in b.queues["q"]]
    # c was already waiting; expired a/b requeue BEHIND it, in pull order
    assert order == ["c", "a", "b"]


def test_requeue_front_flag_restores_queue_jumping():
    clock = FakeClock()
    b = Broker(clock_fn=clock, lease=5.0, requeue_front=True)
    b.handle({"op": "push", "queue": "q", "msg": {"m": "a"}})
    b.handle({"op": "pull", "queue": "q"})
    b.handle({"op": "push", "queue": "q", "msg": {"m": "c"}})
    clock.t = 6.0
    b.handle({"op": "depth", "queue": "q"})
    assert [m["m"] for m in b.queues["q"]] == ["a", "c"]   # jumped the head
    # per-op override on nack, both directions
    b2 = Broker()
    b2.handle({"op": "push_many", "queue": "q", "msgs": [{"m": 1}, {"m": 2}]})
    t1 = b2.handle({"op": "pull", "queue": "q"})["tag"]
    b2.handle({"op": "nack", "tag": t1})                    # default: FIFO
    assert [m["m"] for m in b2.queues["q"]] == [2, 1]
    t2 = b2.handle({"op": "pull", "queue": "q"})["tag"]
    b2.handle({"op": "nack", "tag": t2, "requeue_front": True})
    assert [m["m"] for m in b2.queues["q"]] == [2, 1]


def test_redelivery_keeps_try_metadata_intact():
    clock = FakeClock()
    b = Broker(clock_fn=clock, lease=5.0)
    msg = {"dag": "d", "task": "t", "kind": "python", "payload": {}, "try": 3}
    b.handle({"op": "push", "queue": "q", "msg": msg})
    b.handle({"op": "pull", "queue": "q"})
    clock.t = 6.0
    got = b.handle({"op": "pull", "queue": "q"})["msg"]
    assert got == msg and got["try"] == 3


def test_broker_ops_never_scan_live_leases():
    """The O(log n) gate: with N live (unexpired) leases, an op pays one heap
    peek — zero pops — and expiry later pops exactly the due entries."""
    clock = FakeClock()
    b = Broker(clock_fn=clock, lease=100.0)
    b.handle({"op": "push_many", "queue": "q",
              "msgs": [{"i": i} for i in range(500)]})
    b.handle({"op": "pull_many", "queue": "q", "max_n": 500})
    assert len(b.inflight) == 500
    b.stats.clear()
    clock.t = 50.0                           # nothing due yet
    for _ in range(100):
        b.handle({"op": "push", "queue": "other", "msg": {}})
        b.handle({"op": "depth", "queue": "q"})
    assert b.stats["expire_scanned"] == 0    # 200 ops, zero heap pops
    clock.t = 101.0
    b.handle({"op": "depth", "queue": "q"})
    assert b.stats["expire_scanned"] == 500  # each due lease popped once
    assert b.stats["redelivered"] == 500
    assert b._expiry_heap == [] and not b.inflight


# ------------------------------------------------------------------ taskdb
def test_upsert_many_matches_sequential_upserts():
    rows = [
        {"dag": "d", "task": "a", "try": 1, "status": "running", "clock": 0.0},
        {"dag": "d", "task": "a", "try": 1, "status": "success",
         "result": {"x": 1}, "clock": 1.0},
        {"dag": "d", "task": "b", "try": 2, "status": "failed",
         "error": "boom", "clock": 1.0},
    ]
    one, many = TaskDB(), TaskDB()
    for r in rows:
        one.handle({"op": "upsert", **r})
    resp = many.handle({"op": "upsert_many", "rows": rows})
    assert resp["n"] == 3
    s1 = one.handle({"op": "dag_state", "dag": "d"})["tasks"]
    s2 = many.handle({"op": "dag_state", "dag": "d"})["tasks"]
    assert s1 == s2
    d1 = one.handle({"op": "dag_delta", "dag": "d", "since": 0})
    d2 = many.handle({"op": "dag_delta", "dag": "d", "since": 0})
    assert d1["tasks"] == d2["tasks"]


# ------------------------------------------------- worker commit pipelining
class LocalClient:
    """In-process broker+taskdb behind the ServiceClient interface, counting
    (service, op) round-trips."""

    def __init__(self, broker: Broker, db: TaskDB):
        self.broker = broker
        self.db = db
        self.calls = Counter()

    def call(self, service, msg):
        self.calls[(service, msg["op"])] += 1
        return (self.broker.handle if service == "broker"
                else self.db.handle)(msg)


def test_worker_commits_batch_in_three_rpcs():
    broker, db = Broker(), TaskDB()
    client = LocalClient(broker, db)
    broker.handle({"op": "push_many", "queue": "default", "msgs": [
        {"dag": "d", "task": f"t{i}", "kind": "python", "payload": {"i": i},
         "try": 1} for i in range(8)]})
    w = PipelineWorker(client, "w0", batch=8)
    client.calls.clear()
    done = w.tick()
    assert done == [f"d.t{i}" for i in range(8)]
    assert client.calls == Counter({("broker", "pull_many"): 1,
                                    ("taskdb", "upsert_many"): 1,
                                    ("broker", "ack_many"): 1})
    state = db.handle({"op": "dag_state", "dag": "d"})["tasks"]
    assert all(state[f"t{i}"]["status"] == "success" for i in range(8))
    assert all(state[f"t{i}"]["worker"] == "w0" for i in range(8))
    assert not broker.inflight                     # batch fully acked


# ------------------------------------------------------- scheduler batching
def test_scheduler_coalesces_frontier_into_batched_rpcs():
    db = TaskDB()
    client = LocalClient(Broker(), db)
    sched = Scheduler(client)
    tasks = [Task(f"t{i}") for i in range(40)]
    tasks += [Task(f"p{i}", requires=("onprem",)) for i in range(10)]
    sched.add_dag(DAG("d", tasks))
    client.calls.clear()
    scheduled = sched.tick()
    assert len(scheduled) == 50
    # one probe + one row batch + one push batch PER QUEUE (two queues here)
    assert client.calls == Counter({("taskdb", "dag_delta_many"): 1,
                                    ("taskdb", "upsert_many"): 1,
                                    ("broker", "push_many"): 2})
    assert len(client.broker.queues["default"]) == 40
    assert len(client.broker.queues["onprem"]) == 10


def test_dag_status_never_issues_dag_state_roundtrip():
    db = TaskDB()
    client = LocalClient(Broker(), db)
    sched = Scheduler(client)
    sched.add_dag(DAG("d", [Task("a"), Task("b", upstream=("a",))]))
    sched.tick()
    assert sched.dag_status("d") == {"a": "queued", "b": "pending"}
    db.handle({"op": "upsert", "dag": "d", "task": "a", "try": 1,
               "status": "success", "clock": 1.0})
    # out-of-band write is visible through the cached state via the probe
    assert sched.dag_status("d")["a"] == "success"
    assert not sched.dag_done("d")
    assert client.calls[("taskdb", "dag_state")] == 0
    assert client.calls[("taskdb", "dag_delta_many")] > 0
    # ground truth: cache agrees with a real dag_state dump
    truth = db.handle({"op": "dag_state", "dag": "d"})["tasks"]
    for t, s in sched.dag_status("d").items():
        assert truth.get(t, {}).get("status", "pending") == s


def test_observation_probe_does_not_lose_scheduling_work():
    """dag_status consuming the delta that carries a failure must not starve
    the retry: the staged retry/fail work survives the observation probe."""
    db = TaskDB()
    client = LocalClient(Broker(), db)
    sched = Scheduler(client)
    sched.add_dag(DAG("d", [Task("a", retries=1)]))
    sched.tick()
    sched.tick()                                   # quiescent now
    db.handle({"op": "upsert", "dag": "d", "task": "a", "try": 1,
               "status": "failed", "clock": 1.0})
    assert sched.dag_status("d")["a"] == "failed"  # probe eats the delta
    scheduled = sched.tick()                       # retry still happens
    assert scheduled == ["d.a#retry2"]


# --------------------------------------------- pipeline-level equivalence
def _flaky_composer(pipelined: bool):
    plane = ManagementPlane()
    plane.add_cluster("master", is_master=True)
    plane.add_cluster("onprem-a")
    comp = HybridComposer(
        plane, workers={"onprem-a": ["w0"]}, pipelined=pipelined,
        worker_batch=4)
    attempts = Counter()

    def flaky(payload):
        attempts[payload["name"]] += 1
        if attempts[payload["name"]] <= payload.get("fail_times", 0):
            raise RuntimeError(f"boom {attempts[payload['name']]}")
        return {"attempts": attempts[payload["name"]]}

    for w in comp.workers:
        w.register("flaky", flaky)
    dag = DAG("e", [
        Task("root", kind="python"),
        Task("retry_ok", kind="flaky", upstream=("root",), retries=2,
             payload={"name": "retry_ok", "fail_times": 2}),
        Task("dead", kind="flaky", upstream=("root",), retries=1,
             payload={"name": "dead", "fail_times": 99}),
        Task("after_dead", kind="python", upstream=("dead",)),
        Task("join", kind="python", upstream=("retry_ok",)),
    ])
    comp.add_dag(dag)
    comp.run_dag("e", max_ticks=120)
    rows = comp.taskdb.handle({"op": "dag_state", "dag": "e"})["tasks"]
    return {t: (r["status"], r["try"]) for t, r in rows.items()}


def test_sync_vs_batched_pipeline_equivalence():
    """Same DAG, same flaky tasks: the batched data plane must land on exactly
    the terminal (status, try) table the per-task protocol produces."""
    sync = _flaky_composer(pipelined=False)
    batched = _flaky_composer(pipelined=True)
    assert sync == batched
    assert batched["retry_ok"] == ("success", 3)
    assert batched["dead"] == ("failed", 2)
    assert batched["after_dead"] == ("upstream_failed", 1)
    assert batched["join"] == ("success", 1)


def test_worker_death_redelivery_under_batched_pulls():
    plane = ManagementPlane()
    plane.add_cluster("master", is_master=True)
    plane.add_cluster("onprem-a")
    comp = HybridComposer(plane, workers={"onprem-a": ["w0"]},
                          worker_batch=8)
    comp.broker.lease = 5.0
    dag = DAG("d", [Task(f"t{i}", kind="python") for i in range(6)])
    comp.add_dag(dag)
    comp.scheduler.tick()                          # frontier on the broker
    # a doomed worker leases the whole batch and dies before committing
    dead = comp.broker.handle({"op": "pull_many", "queue": "default",
                               "max_n": 8})
    assert len(dead["msgs"]) == 6
    plane.tick(n=7)                                # lease lapses
    assert comp.run_dag("d", max_ticks=40)
    rows = comp.taskdb.handle({"op": "dag_state", "dag": "d"})["tasks"]
    # redelivered instances, not fresh tries: still try 1, all succeeded
    assert all(r["status"] == "success" and r["try"] == 1
               for r in rows.values())
    assert comp.broker.stats["redelivered"] == 6


# --------------------------------------------------- depth-aware placement
def _depth_plane():
    plane = ManagementPlane()
    plane.add_cluster("master", is_master=True,
                      local_plane=SimLocalPlane(caps=("control",)))
    plane.add_cluster("pub-a", local_plane=SimLocalPlane(caps=("cpu",)))
    plane.add_cluster("priv-a",
                      local_plane=SimLocalPlane(caps=("cpu", "onprem")))
    return plane


def test_dispatcher_queue_depth_view_tracks_publishes():
    plane = _depth_plane()
    plane.overwatch.handle({"op": "put", "key": "/queues/onprem",
                            "value": {"ready": 7, "inflight": 2}})
    assert plane.dispatcher.queue_depths()["onprem"] == {"ready": 7,
                                                         "inflight": 2}
    plane.overwatch.handle({"op": "delete", "key": "/queues/onprem"})
    assert "onprem" not in plane.dispatcher.queue_depths()


def test_worker_pod_placement_follows_deep_queue():
    plane = _depth_plane()
    # load would point at pub-a; the deep compliance queue must win instead
    plane.overwatch.handle({"op": "put", "key": "/telemetry/pub-a",
                            "value": {"load": 0.0}})
    plane.overwatch.handle({"op": "put", "key": "/telemetry/priv-a",
                            "value": {"load": 3.0}})
    plane.overwatch.handle({"op": "put", "key": "/queues/onprem",
                            "value": {"ready": 50, "inflight": 0}})
    job = {"job_id": "wp-1", "kind": "sim", "steps": 1,
           "tags": {"requires": ("cpu",), "queues": ["onprem", "default"]}}
    # only priv-a's capabilities cover the deep queue's tags
    assert plane.dispatcher.pick(job) == "priv-a"
    assert plane.dispatcher.submit_many([job]) == ["priv-a"]
    # drained queue -> bias gone, least-loaded wins again
    plane.overwatch.handle({"op": "put", "key": "/queues/onprem",
                            "value": {"ready": 0, "inflight": 0}})
    job2 = {"job_id": "wp-2", "kind": "sim", "steps": 1,
            "tags": {"requires": ("cpu",), "queues": ["onprem", "default"]}}
    assert plane.dispatcher.pick(job2) == "pub-a"


def test_composer_publishes_queue_depths_on_sweep_cadence():
    plane = ManagementPlane()
    plane.add_cluster("master", is_master=True)
    # no onprem-capable worker: the compliance queue backs up
    comp = HybridComposer(plane, workers={"master": ["w0"]})
    dag = DAG("d", [Task(f"p{i}", kind="python", requires=("onprem",))
                    for i in range(4)])
    comp.add_dag(dag)
    comp.tick()
    depth = plane.overwatch.handle({"op": "get",
                                    "key": "/queues/onprem"})["value"]
    assert depth["ready"] == 4 and depth["inflight"] == 0
    assert plane.dispatcher.queue_depths()["onprem"]["ready"] == 4
    # steady state: no depth movement -> no re-publish (coalesce-friendly)
    puts_before = plane.overwatch.op_counts["put"]
    comp.tick()
    comp.tick()
    depth_puts = sum(1 for _, op, key, _v in plane.overwatch.op_log
                     if op == "put" and key.startswith("/queues/"))
    assert depth_puts == 1
    assert plane.overwatch.op_counts["put"] >= puts_before  # other telemetry ok


def test_pipeline_still_completes_with_depth_publication():
    plane = ManagementPlane()
    plane.add_cluster("master", is_master=True)
    plane.add_cluster("onprem-a")
    comp = HybridComposer(
        plane, workers={"master": ["w-pub"], "onprem-a": ["w-priv"]},
        worker_queues={"w-pub": ("default",), "w-priv": ("onprem", "default")})
    dag = DAG("run", [
        Task("a", kind="python"),
        Task("b", kind="python", upstream=("a",), requires=("onprem",)),
        Task("c", kind="python", upstream=("b",)),
    ])
    comp.add_dag(dag)
    assert comp.run_dag("run", max_ticks=60)
    # the drained queues were tombstoned out of the published view entirely —
    # no stale 0/0 keys linger once a queue empties
    assert plane.dispatcher.queue_depths() == {}
    assert plane.overwatch.handle(
        {"op": "range", "prefix": "/queues/"})["items"] == {}
