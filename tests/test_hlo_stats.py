"""hlo_stats parser: synthetic-HLO unit tests + a live end-to-end check where
ground truth is computable by hand (the while-trip multiplication XLA's own
cost analysis misses).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.roofline.hlo_stats import (_iota_groups, groups_cross_pod,
                                      module_stats, parse_module)

SYNTH = """
HloModule m

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %dot.1 = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8]{1,0} all-reduce(%dot.1), channel_id=1, replica_groups=[2,4]<=[8], to_apply=%sum
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%ni, %ar)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8]{1,0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8,8]) tuple(%z, %a)
  %w = (s32[], f32[8,8]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""


def test_while_trip_multiplication():
    st = module_stats(SYNTH, pod_size=4, n_devices=8)
    # dot: 2 * 8*8 * 8 = 1024 flops, x10 trips
    assert st.flops == 10 * 1024
    # all-reduce operand: 8*8*4 bytes = 256, x10
    assert st.collective_bytes == 10 * 256
    # groups [2,4]<=[8]: {0..3},{4..7} -> each inside one pod of size 4
    assert st.cross_pod_bytes == 0


def test_iota_replica_groups():
    assert _iota_groups("[2,4]<=[8]") == [[0, 1, 2, 3], [4, 5, 6, 7]]
    # transposed: [4,2]<=[2,4]T(1,0) -> ids reshaped (2,4), transposed -> (4,2)
    got = _iota_groups("[4,2]<=[2,4]T(1,0)")
    assert got == [[0, 4], [1, 5], [2, 6], [3, 7]]


def test_cross_pod_classification():
    # groups of stride-crossing members span pods
    attrs = "replica_groups=[4,2]<=[2,4]T(1,0), channel_id=1"
    assert groups_cross_pod(attrs, pod_size=4, n_devices=8) is True
    attrs = "replica_groups=[2,4]<=[8], channel_id=1"
    assert groups_cross_pod(attrs, pod_size=4, n_devices=8) is False
    # explicit lists
    attrs = "replica_groups={{0,1},{2,3}}"
    assert groups_cross_pod(attrs, pod_size=2, n_devices=4) is False
    attrs = "replica_groups={{0,2},{1,3}}"
    assert groups_cross_pod(attrs, pod_size=2, n_devices=4) is True


def test_parse_module_finds_entry_and_instrs():
    comps, entry = parse_module(SYNTH)
    assert entry == "main"
    assert {c for c in comps} >= {"body", "cond", "main"}
    ops = [i.opcode for i in comps["body"].instrs]
    assert "dot" in ops and "all-reduce" in ops


def test_live_scan_flops_ground_truth():
    """XLA cost analysis counts a scanned body once; ours multiplies."""
    L, B, D = 4, 8, 32
    W = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    x = jax.ShapeDtypeStruct((B, D), jnp.float32)

    def f(w, x):
        return jax.lax.scan(lambda c, wi: (c @ wi, None), x, w)[0]

    compiled = jax.jit(f).lower(W, x).compile()
    st = module_stats(compiled.as_text(), pod_size=0, n_devices=1)
    want = L * 2 * B * D * D
    assert abs(st.flops - want) / want < 0.05
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):        # older jax returns [dict]
        ca = ca[0]
    xla = ca["flops"]
    assert xla < want            # documents the undercount we correct
