"""MeshPlan sharding-rule properties (hypothesis) + production-mesh specs."""
import jax
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.launch.mesh import make_test_mesh
from repro.parallel.sharding import DEFAULT_RULES, MeshPlan

LOGICALS = [l for l in DEFAULT_RULES if l is not None]


@pytest.fixture(scope="module")
def plan():
    return MeshPlan(mesh=make_test_mesh(), fsdp=True)


def _entries(spec: P):
    out = []
    for e in spec:
        if e is None:
            continue
        out.extend(e if isinstance(e, tuple) else (e,))
    return out


@settings(max_examples=80, deadline=None)
@given(st.lists(st.sampled_from(LOGICALS + [None]), min_size=1, max_size=5),
       st.lists(st.sampled_from([1, 2, 3, 4, 6, 8, 16, 64, 128, 151936]),
                min_size=1, max_size=5))
def test_spec_never_reuses_axis_and_divides(axes, dims):
    n = min(len(axes), len(dims))
    axes, dims = axes[:n], dims[:n]
    plan = MeshPlan(mesh=make_test_mesh(), fsdp=True)
    spec = plan.spec(axes, dims)
    used = _entries(spec)
    assert len(used) == len(set(used))            # PartitionSpec invariant
    # every kept mesh axis divides its dimension
    for d, entry in zip(dims, list(spec) + [None] * (n - len(spec))):
        if entry is None:
            continue
        group = entry if isinstance(entry, tuple) else (entry,)
        total = int(np.prod([plan.axis_size(a) for a in group]))
        assert d % total == 0


def test_batch_pod_data_on_production_shapes():
    # simulated production mesh via axis sizes (no devices needed for spec math)
    class FakeMesh:
        shape = {"pod": 2, "data": 16, "model": 16}
        axis_names = ("pod", "data", "model")
    plan = MeshPlan(mesh=FakeMesh(), fsdp=True)
    assert plan.spec(("batch", "seq"), (256, 4096)) == P(("pod", "data"))
    assert plan.spec(("vocab", "embed"), (151936, 5120)) == \
        P("model", "data")
    # opt state: embed dim spreads over pod too (ZeRO)
    assert plan.opt_spec(("vocab", "embed"), (151936, 5120)) == \
        P("model", ("pod", "data"))
    # non-divisible dims drop axes (24 heads on model=16)
    assert plan.spec(("embed", "heads", None), (3072, 24, 128)) == P("data")


def test_sp_switch_shards_sequence():
    class FakeMesh:
        shape = {"pod": 2, "data": 16, "model": 16}
        axis_names = ("pod", "data", "model")
    base = MeshPlan(mesh=FakeMesh(), fsdp=True, sp=False)
    sp = MeshPlan(mesh=FakeMesh(), fsdp=True, sp=True)
    assert base.spec(("batch", "seq", None), (256, 4096, 5120)) == \
        P(("pod", "data"))
    assert sp.spec(("batch", "seq", None), (256, 4096, 5120)) == \
        P(("pod", "data"), "model")


def test_constrain_applies_on_real_device(plan):
    import jax.numpy as jnp
    from repro.parallel.sharding import constrain
    x = jnp.ones((4, 8))
    y = jax.jit(lambda t: constrain(t, plan, ("batch", "embed")))(x)
    assert (np.asarray(y) == 1).all()
