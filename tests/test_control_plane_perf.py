"""Hot-path complexity guarantees of the control-plane overhaul.

These tests pin the *shape* of the cost, not wall-time: a dispatch must issue a
constant number of overwatch ops no matter how many jobs already exist, range
scans must come off the prefix index, watches must be bucket-routed, lease
sweeps heap-driven, and quiescent DAGs must cost a single delta probe per tick.
"""
from collections import Counter

import pytest

from repro.core.plane import ManagementPlane
from repro.core.transport import Fabric, RingLog
from repro.pipelines.dag import DAG, Task
from repro.pipelines.scheduler import Scheduler
from repro.pipelines.taskdb import TaskDB
from tests.conftest import make_plane


def _preload_jobs(plane: ManagementPlane, n: int, cluster: str) -> None:
    for j in range(n):
        plane.overwatch.handle(
            {"op": "put", "key": f"/jobs/pre-{j}/placement",
             "value": {"cluster": cluster,
                       "job": {"job_id": f"pre-{j}", "kind": "sim",
                               "steps": 10, "tags": {}, "payload": {}},
                       "clock": 0.0}})
        plane.overwatch.handle(
            {"op": "put", "key": f"/jobs/pre-{j}/status",
             "value": {"cluster": cluster, "status": "running",
                       "progress": 1.0, "rate": 1.0, "clock": 0.0}})


def _submit_op_delta(plane: ManagementPlane, job_id: str) -> Counter:
    before = Counter(plane.overwatch.op_counts)
    plane.submit_job("sim", steps=1, job_id=job_id)
    return Counter(plane.overwatch.op_counts) - before


def test_submit_overwatch_ops_independent_of_job_count():
    """A single submit() performs O(1) overwatch ops — in particular zero range
    scans — regardless of how many jobs already exist in the keyspace."""
    plane = make_plane(2)
    delta_small = _submit_op_delta(plane, "first")
    _preload_jobs(plane, 400, "onprem-0")
    delta_large = _submit_op_delta(plane, "second")
    assert delta_small == delta_large          # same op profile at 1x and 400x
    assert delta_large["range"] == 0           # dispatcher views, not scans
    assert sum(delta_large.values()) <= 5      # a small constant


def test_range_prefix_index_correctness(plane):
    ow = plane.agents["onprem-a"].ow
    ow.put("/a", 0)
    ow.put("/a/x", 1)
    ow.put("/a/y", 2)
    ow.put("/ab", 3)
    ow.put("/b/z", 4)
    assert ow.range("/a/") == {"/a/x": 1, "/a/y": 2}
    assert list(ow.range("/a")) == ["/a", "/a/x", "/a/y", "/ab"]  # sorted
    ow.delete("/a/x")
    assert ow.range("/a/") == {"/a/y": 2}
    # empty prefix = full keyspace (clusters/telemetry keys included)
    full = plane.overwatch.handle({"op": "range", "prefix": ""})["items"]
    assert "/a/y" in full and "/clusters/onprem-a" in full


def test_watch_bucket_routing_and_order(plane):
    events = []
    ow = plane.overwatch
    ow.watch("", lambda e, k, v, r: events.append(("all", k)))
    ow.watch("/x/", lambda e, k, v, r: events.append(("x", k)))
    ow.watch("/y/", lambda e, k, v, r: events.append(("y", k)))
    ow.handle({"op": "put", "key": "/x/k", "value": 1})
    assert events == [("all", "/x/k"), ("x", "/x/k")]  # registration order
    events.clear()
    ow.handle({"op": "put", "key": "/y/k", "value": 2})
    assert events == [("all", "/y/k"), ("y", "/y/k")]  # /x/ watcher skipped


def test_lease_heap_with_keepalives():
    plane = make_plane(1)
    ow = plane.agents["onprem-0"].ow
    lease = ow.lease_grant(ttl=2.0)
    ow.put("/svc/a", 1, lease=lease)
    for _ in range(5):                       # stale heap entries accumulate
        plane.tick()
        ow.lease_keepalive(lease)
    assert ow.get("/svc/a") == 1             # keepalive honored
    plane.tick(n=5)                          # now let it lapse
    assert ow.get("/svc/a") is None


def test_ring_log_bounds_memory():
    log = RingLog(limit=3)
    for i in range(10):
        log.append(i)
    assert list(log) == [7, 8, 9]
    assert len(log) == 3 and log.total_appended == 10
    assert log[-1] == 9 and log[-2:] == [8, 9]
    unbounded = RingLog(None)
    for i in range(10):
        unbounded.append(i)
    assert len(unbounded) == 10

    fabric = Fabric(message_log_limit=5)
    fabric.register_handler("c", ("ip", 1), lambda p: {"ok": True})
    for _ in range(20):
        fabric.send("c", "pod", "c", ("ip", 1), {"x": 1})
    assert len(fabric.message_log) == 5
    assert fabric.message_log.total_appended == 20


def test_timer_heap_ordering_and_rearm():
    fabric = Fabric()
    fired = []
    fabric.call_later(2.0, lambda: fired.append("b"))
    fabric.call_later(1.0, lambda: fired.append("a"))
    fabric.call_later(2.0, lambda: fired.append("c"))
    # a timer re-armed during a tick waits for the next tick
    fabric.call_later(1.0, lambda: fabric.call_later(0.0,
                                                     lambda: fired.append("d")))
    fabric.tick(2.0)
    assert fired == ["a", "b", "c"]          # deadline order, FIFO on ties
    fabric.tick(1.0)
    assert fired == ["a", "b", "c", "d"]


def test_straggler_rule_garbage_collected():
    plane = make_plane(3, rates={0: 1.0, 1: 1.0, 2: 0.01})
    pinning = {"on": True}
    for i in range(3):
        plane.add_routing_rule(__import__(
            "repro.core.dispatcher", fromlist=["RoutingRule"]).RoutingRule(
            name=f"pin-j{i}",
            match=lambda j, _i=i: pinning["on"] and j["job_id"] == f"j{_i}",
            clusters=[f"onprem-{i}"]))
    jids = [plane.submit_job("sim", steps=6, job_id=f"j{i}",
                             tags={"requires": ("cpu",)}) for i in range(3)]
    pinning["on"] = False
    plane.tick(n=3)
    moved = plane.dispatcher.check_stragglers()
    assert moved
    assert any(r.name.startswith("straggler-") for r in plane.dispatcher.rules)
    assert plane.run_until_done(jids, max_ticks=60)
    # the mitigated job completed -> its routing rule must be gone
    assert not any(r.name.startswith("straggler-")
                   for r in plane.dispatcher.rules)


def test_straggler_rule_replaced_when_job_straggles_again():
    """A job that straggles twice must end with zero rules once done — the
    first straggle's rule is replaced, not orphaned."""
    plane = make_plane(4, rates={0: 1.0, 1: 0.01, 2: 0.01, 3: 1.0})
    pinning = {"on": True}
    from repro.core.dispatcher import RoutingRule
    pins = {"jf0": "onprem-0", "jf1": "onprem-3", "js": "onprem-1"}
    for jid, cl in pins.items():
        plane.add_routing_rule(RoutingRule(
            name=f"pin-{jid}",
            match=lambda j, _jid=jid: pinning["on"] and j["job_id"] == _jid,
            clusters=[cl]))
    jids = [plane.submit_job("sim", steps=8, job_id=j,
                             tags={"requires": ("cpu",)}) for j in pins]
    pinning["on"] = False
    plane.tick(n=2)
    moved1 = plane.dispatcher.check_stragglers()
    assert any(m.startswith("js:onprem-1->") for m in moved1)
    # least-loaded re-dispatch lands on the idle (also slow) onprem-2
    assert plane.overwatch.handle(
        {"op": "get", "key": "/jobs/js/placement"})["value"]["cluster"] == "onprem-2"
    plane.tick(n=2)
    moved2 = plane.dispatcher.check_stragglers()
    assert any(m.startswith("js:onprem-2->") for m in moved2)
    straggler_rules = [r for r in plane.dispatcher.rules
                       if r.name.startswith("straggler-")]
    assert len(straggler_rules) == 1          # replaced, not accumulated
    # ...and the replacement carries both exclusions forward
    assert set(straggler_rules[0].clusters).isdisjoint(
        {"onprem-1", "onprem-2"})
    assert plane.run_until_done(jids, max_ticks=80)
    assert not any(r.name.startswith("straggler-")
                   for r in plane.dispatcher.rules)


def test_taskdb_dag_delta_cursor():
    db = TaskDB()
    r = db.handle({"op": "dag_delta", "dag": "d", "since": 0})
    assert r["tasks"] == {}
    db.handle({"op": "upsert", "dag": "d", "task": "a", "try": 1,
               "status": "queued", "clock": 0.0})
    db.handle({"op": "upsert", "dag": "d", "task": "b", "try": 1,
               "status": "queued", "clock": 0.0})
    r1 = db.handle({"op": "dag_delta", "dag": "d", "since": r["cursor"]})
    assert set(r1["tasks"]) == {"a", "b"}
    # no changes since cursor -> empty delta
    r2 = db.handle({"op": "dag_delta", "dag": "d", "since": r1["cursor"]})
    assert r2["tasks"] == {}
    db.handle({"op": "upsert", "dag": "d", "task": "a", "try": 2,
               "status": "failed", "clock": 1.0})
    r3 = db.handle({"op": "dag_delta", "dag": "d", "since": r2["cursor"]})
    assert set(r3["tasks"]) == {"a"} and r3["tasks"]["a"]["try"] == 2
    # delta view agrees with the full dag_state view
    state = db.handle({"op": "dag_state", "dag": "d"})["tasks"]
    assert state["a"]["try"] == 2 and state["b"]["status"] == "queued"


def test_taskdb_changelog_compacts():
    db = TaskDB()
    for i in range(500):
        db.handle({"op": "upsert", "dag": "d", "task": "only", "try": 1,
                   "status": "running", "clock": float(i)})
    assert len(db._changes["d"]) < 100       # compacted, not 500 entries
    r = db.handle({"op": "dag_delta", "dag": "d", "since": 0})
    assert set(r["tasks"]) == {"only"}


class _CountingClient:
    def __init__(self, taskdb):
        self.taskdb = taskdb
        self.calls = Counter()

    def call(self, service, msg):
        self.calls[service] += 1
        if service == "taskdb":
            return self.taskdb.handle(msg)
        return {"ok": True}                  # broker stub


def test_scheduler_quiescent_dag_is_one_probe_per_tick():
    db = TaskDB()
    client = _CountingClient(db)
    sched = Scheduler(client)
    sched.add_dag(DAG("d", [Task("a"), Task("b", upstream=("a",))]))
    sched.tick()                             # schedules root "a"
    sched.tick()                             # sees own queued row, settles
    for t in ("a", "b"):                     # complete everything out of band
        db.handle({"op": "upsert", "dag": "d", "task": t, "try": 1,
                   "status": "success", "clock": 0.0})
    sched.tick()                             # drains the success delta ("b" ran)
    sched.tick()
    client.calls.clear()
    for _ in range(10):
        sched.tick()
    assert client.calls == Counter({"taskdb": 10})  # one delta probe per tick


def test_dispatcher_views_track_cluster_lifecycle(plane):
    d = plane.dispatcher
    assert set(d.clusters()) == {"master", "onprem-a", "onprem-b"}
    plane.fabric.partition_cluster("onprem-b")
    plane.tick(n=8)                          # lease expires -> tombstone
    assert "onprem-b" not in d.clusters()
    assert all(name != "onprem-b" for _, name in d._load_order)
    jid = plane.submit_job("sim", steps=5)
    placed = plane.overwatch.handle(
        {"op": "get", "key": f"/jobs/{jid}/placement"})["value"]
    assert placed["cluster"] != "onprem-b"
