"""Per-family broker sharding: deterministic routing, single-shard
equivalence, family isolation across shard endpoints, per-family depth
filtering, and tombstone propagation from a sharded fleet."""
from collections import Counter

from repro.autoscale.policy import ScalingPolicy
from repro.core.plane import ManagementPlane, SimLocalPlane
from repro.pipelines.broker import Broker, BrokerRouter, broker_service_names
from repro.pipelines.composer import HybridComposer
from repro.pipelines.dag import DAG, Task


def _msg(task, queue_kind="python"):
    return {"dag": "d", "task": task, "kind": queue_kind, "payload": {},
            "try": 1}


def _two_shard_queues(router):
    """Two queue names that land on different shards (deterministic, so
    probe a few candidates rather than hardcoding hash outcomes)."""
    q0 = "default"
    s0 = router.shard_for_queue(q0)
    for cand in ("onprem", "gpu", "etl", "train", "eval", "export", "q7"):
        if router.shard_for_queue(cand) != s0:
            return q0, cand
    raise AssertionError("no second-shard queue among candidates")


# ---------------------------------------------------------------- the router
def test_router_single_shard_is_identity():
    r = BrokerRouter(1)
    for q in ("default", "onprem", "a,b,c"):
        assert r.shard_for_queue(q) == 0
        assert r.service_for_queue(q) == "broker"
    assert broker_service_names(1) == ("broker",)


def test_router_deterministic_and_spreading():
    r1, r2 = BrokerRouter(4), BrokerRouter(4)
    queues = [f"fam-{i}" for i in range(64)]
    placement = [r1.shard_for_queue(q) for q in queues]
    # pure function of the name: a fresh ring agrees (client/server contract)
    assert placement == [r2.shard_for_queue(q) for q in queues]
    assert all(0 <= s < 4 for s in placement)
    assert len(set(placement)) > 1          # families actually spread
    assert broker_service_names(4) == ("broker-s0", "broker-s1",
                                       "broker-s2", "broker-s3")
    for q in queues:
        assert r1.service_for_queue(q) == f"broker-s{r1.shard_for_queue(q)}"


# --------------------------------------------------------- composer plumbing
def _run_dag(broker_shards):
    plane = ManagementPlane(coalesce_watches=True)
    plane.add_cluster("master", is_master=True,
                      local_plane=SimLocalPlane(caps=("control",)))
    plane.add_cluster("onprem",
                      local_plane=SimLocalPlane(caps=("cpu", "onprem")))
    comp = HybridComposer(plane, {"master": ["w-m"], "onprem": ["w-o"]},
                          worker_queues={"w-m": ("default",),
                                         "w-o": ("default", "onprem")},
                          broker_shards=broker_shards)
    tasks = [Task("a", kind="python", payload={"x": 1}),
             Task("b", kind="python", upstream=("a",)),
             Task("c", kind="python", upstream=("a",), requires=("onprem",)),
             Task("d", kind="python", upstream=("b", "c"))]
    comp.add_dag(DAG("d1", tasks))
    ok = comp.run_dag("d1", max_ticks=60)
    return comp, ok


def test_single_and_sharded_runs_are_equivalent():
    comp1, ok1 = _run_dag(1)
    comp2, ok2 = _run_dag(2)
    assert ok1 and ok2
    st1 = comp1.scheduler.dag_status("d1")
    st2 = comp2.scheduler.dag_status("d1")
    assert st1 == st2 == {t: "success" for t in ("a", "b", "c", "d")}
    # identical terminal rows (workers differ only in which endpoint they
    # dialed, not in what they committed)
    rows1 = {k: v["status"] for k, v in comp1.taskdb.rows.items()}
    rows2 = {k: v["status"] for k, v in comp2.taskdb.rows.items()}
    assert rows1 == rows2


def test_disjoint_families_live_on_disjoint_shards():
    comp, ok = _run_dag(2)
    assert ok
    s_default = comp.router.shard_for_queue("default")
    s_onprem = comp.router.shard_for_queue("onprem")
    per_shard_ops = [dict(b.op_counts) for b in comp.brokers]
    if s_default == s_onprem:
        # both families hashed together: the other shard saw NOTHING
        other = comp.brokers[1 - s_default]
        assert sum(other.op_counts.values()) == 0
    else:
        # each family's ops hit only its owner — no serialization through
        # one handler, and both shards did real work
        for shard_ops in per_shard_ops:
            assert shard_ops.get("push_many", 0) > 0
            assert shard_ops.get("ack_many", 0) > 0
        assert set(comp.brokers[s_default].queues) <= {"default"}
        assert set(comp.brokers[s_onprem].queues) <= {"onprem"}


def test_sharded_appspec_keeps_single_shard_shape():
    plane = ManagementPlane()
    plane.add_cluster("master", is_master=True)
    comp = HybridComposer(plane, {"master": ["w0"]})
    assert sorted(s.name for s in comp.spec.services) == ["broker", "taskdb"]
    plane2 = ManagementPlane()
    plane2.add_cluster("master", is_master=True)
    comp2 = HybridComposer(plane2, {"master": ["w0"]}, broker_shards=3)
    assert sorted(s.name for s in comp2.spec.services) == [
        "broker-s0", "broker-s1", "broker-s2", "taskdb"]
    # every worker pod is wired to every shard service + the taskdb
    pod = next(p for p in comp2.spec.pods if p.name == "w0")
    assert set(pod.needs) == {"broker-s0", "broker-s1", "broker-s2",
                              "taskdb"}


# ------------------------------------------------------ per-family filtering
def test_depth_many_families_filter():
    b = Broker()
    b.handle({"op": "push_many", "queue": "q1", "msgs": [_msg("a")]})
    b.handle({"op": "push_many", "queue": "q2", "msgs": [_msg("b"),
                                                        _msg("c")]})
    all_depths = b.handle({"op": "depth_many"})["depths"]
    assert set(all_depths) == {"q1", "q2"}
    only = b.handle({"op": "depth_many", "families": ["q2"]})["depths"]
    assert only == {"q2": {"ready": 2, "inflight": 0}}
    # explicit queue list intersects with the family filter
    mixed = b.handle({"op": "depth_many", "queues": ["q1", "q2"],
                      "families": ["q1"]})["depths"]
    assert set(mixed) == {"q1"}


def test_changed_depths_family_filter_keeps_unowned_dirty():
    b = Broker()
    b.handle({"op": "push", "queue": "mine", "msg": _msg("a")})
    b.handle({"op": "push", "queue": "theirs", "msg": _msg("b")})
    owned = b.changed_depths(families={"mine"})
    assert set(owned) == {"mine"}
    # the unowned queue was NOT silently un-flagged: a later unfiltered
    # call (or its owner's) still reports it
    rest = b.changed_depths()
    assert set(rest) == {"theirs"}
    assert b.changed_depths() == {}


def test_sharded_drained_family_tombstones_propagate():
    comp, ok = _run_dag(2)
    assert ok
    plane = comp.plane
    # every family fully drained -> every /queues/<name> key tombstoned,
    # whichever shard owned it; the depth view carries no stale 0/0 rows
    assert plane.dispatcher.queue_depths() == {}
    for q in ("default", "onprem"):
        assert plane.overwatch.handle(
            {"op": "get", "key": f"/queues/{q}"})["value"] is None


# ----------------------------------------------------- autoscaler integration
def test_autoscaler_rides_sharded_broker():
    plane = ManagementPlane()
    plane.add_cluster("master", is_master=True,
                      local_plane=SimLocalPlane(caps=("control",)))
    plane.add_cluster("onprem-a",
                      local_plane=SimLocalPlane(caps=("cpu",)))
    comp = HybridComposer(plane, workers={}, broker_shards=2, worker_batch=8)
    policy = ScalingPolicy(family="default", queues=("default",),
                           requires=("cpu",), target_depth_per_worker=8,
                           min_replicas=0, max_replicas=3, scale_up_step=3,
                           scale_down_step=3, up_cooldown=0.0,
                           down_cooldown=0.0)
    asc = comp.attach_autoscaler([policy])
    comp.add_dag(DAG("d", [Task(f"t{i}", kind="python") for i in range(40)]))
    peak = 0
    for _ in range(60):
        comp.tick()
        peak = max(peak, asc.replicas("default"))
        if comp.scheduler.dag_success("d", probe=False) and \
                asc.replicas("default") == 0:
            break
    assert comp.scheduler.dag_success("d")
    assert peak > 0 and asc.replicas("default") == 0
    # exactly-once under graceful scale-down, sharded or not
    owner = comp.brokers[comp.router.shard_for_queue("default")]
    assert owner.stats.get("redelivered", 0) == 0
    statuses = Counter(comp.scheduler.dag_status("d").values())
    assert statuses == Counter({"success": 40})
