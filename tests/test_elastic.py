"""Elastic scaling: membership watch, state re-mesh, loss continuity.

The multi-device re-mesh runs in a subprocess with 8 forced host devices
(tests themselves must keep the default single device — see conftest)."""
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.elastic import ElasticController, divisors_mesh
from tests.conftest import make_plane


def test_divisors_mesh():
    assert divisors_mesh(256) == (16, 16)
    assert divisors_mesh(12) == (4, 3)
    assert divisors_mesh(7) == (7, 1)


def test_controller_sees_join_and_leave():
    plane = make_plane(1)
    changes = []
    ElasticController(plane.overwatch, lambda m: changes.append(tuple(m)))
    plane.add_cluster("onprem-9")                      # join
    assert changes and "onprem-9" in changes[-1]
    plane.fabric.partition_cluster("onprem-9")         # leave (lease expiry)
    plane.tick(n=8)
    assert "onprem-9" not in changes[-1]
    assert "master" in changes[-1]


def test_trainer_continues_after_remesh_same_device():
    """Single-device 'remesh' (device_put round-trip) preserves training."""
    from repro.launch.mesh import make_test_mesh
    from repro.parallel.sharding import MeshPlan
    from repro.runtime.elastic import remesh_state
    from repro.runtime.train_loop import Trainer, TrainJobConfig
    tr = Trainer(TrainJobConfig(arch="qwen3-0.6b", steps=4, seq_len=8,
                                global_batch=2))
    tr.run(2)
    loss_before = tr.loss()
    new_plan = MeshPlan(mesh=make_test_mesh(), fsdp=False)
    from repro.models.params import partition_specs
    from repro.optim.adamw import opt_state_specs
    tr.state = remesh_state(
        tr.state, tr.plan, new_plan,
        lambda p: {"params": partition_specs(tr.arch_cfg, p),
                   "opt": opt_state_specs(tr.arch_cfg, p)})
    tr.run(2)
    assert tr.step == 4 and np.isfinite(tr.loss())


SUBPROCESS_REMESH = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import base as configs
    from repro.models.model import Model
    from repro.models.params import partition_specs
    from repro.parallel.sharding import MeshPlan
    from repro.runtime.elastic import remesh_state

    cfg = dataclasses.replace(configs.get("qwen3-0.6b").reduced(), remat="none")
    mesh8 = jax.make_mesh((4, 2), ("data", "model"))
    mesh4 = jax.make_mesh((2, 2), ("data", "model"),
                          devices=jax.devices()[:4])
    plan8, plan4 = MeshPlan(mesh=mesh8), MeshPlan(mesh=mesh4)
    model = Model(cfg, plan8)
    params = model.init_params(jax.random.PRNGKey(0))
    sharded = jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, jax.sharding.NamedSharding(mesh8, s)),
        params, partition_specs(cfg, plan8))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    logits8, _ = jax.jit(Model(cfg, plan8).forward)(sharded, batch)

    # pod shrink: 8 -> 4 devices
    moved = remesh_state(sharded, plan8, plan4,
                         lambda p: partition_specs(cfg, p))
    assert len({d for l in jax.tree_util.tree_leaves(moved)
                for d in l.devices()}) == 4
    logits4, _ = jax.jit(Model(cfg, plan4).forward)(moved, batch)
    np.testing.assert_allclose(np.asarray(logits8, np.float32),
                               np.asarray(logits4, np.float32),
                               rtol=2e-2, atol=2e-2)
    print("REMESH_OK")
""")


def test_remesh_shrink_preserves_function(tmp_path):
    script = tmp_path / "remesh.py"
    script.write_text(SUBPROCESS_REMESH)
    out = subprocess.run([sys.executable, str(script)],
                         cwd=str(Path(__file__).resolve().parents[1]),
                         capture_output=True, text=True, timeout=420)
    assert "REMESH_OK" in out.stdout, out.stderr[-2000:]
