"""The committed examples, run as tests (slow-marked): the end-to-end DAG
example must keep passing its own assertions (cost-aware steering, warm
compiled-step cache, strict eval restore), and the 100M-param trainer must
still learn at a smoke-sized step count."""
import importlib.util
import pathlib

import pytest

EXAMPLES = pathlib.Path(__file__).resolve().parent.parent / "examples"


def _load(name: str):
    spec = importlib.util.spec_from_file_location(name,
                                                  EXAMPLES / f"{name}.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.mark.slow
def test_hybrid_pipeline_example():
    _load("hybrid_pipeline").main()


@pytest.mark.slow
def test_train_100m_example_reduced():
    # smoke-sized: the example's own assertion switches to a loss-is-falling
    # bar below 150 steps
    _load("train_100m").main(["--steps", "40", "--seq-len", "32",
                              "--batch", "2"])
