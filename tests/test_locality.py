"""Cross-boundary traffic overhaul: replica fan-out fault paths, byte-ledger
truth, the worker depth gate, and the fabric send fast-path satellites
(memoized ACL exemptions, incremental byte-cache eviction, message-log skip).
"""
import pytest

from repro.core.plane import ManagementPlane, SimLocalPlane
from repro.core.replica import REPLICA_PREFIXES, LocalReplica
from repro.core.transport import (
    _CACHE_LIMIT, _STR_BYTES_CACHE, AclTable, DeliveryError, Fabric,
    _payload_bytes, _str_bytes)
from repro.pipelines.composer import HybridComposer
from repro.pipelines.dag import DAG, Task


def _fanout_plane(n=2, coalesce=True):
    plane = ManagementPlane(coalesce_watches=coalesce, replica_fanout=True)
    plane.add_cluster("master", is_master=True,
                      local_plane=SimLocalPlane(caps=("control",)))
    for i in range(n):
        plane.add_cluster(f"c{i}")
    plane.tick(n=2)                      # settle; first ships land
    return plane


# ------------------------------------------------------------ local reads
def test_replica_local_read_costs_zero_cross_bytes():
    plane = _fanout_plane()
    agent = plane.agents["c0"]
    before = plane.fabric.cross_cluster_bytes()
    tele = agent.fleet_telemetry(max_lag=2.0)
    assert set(tele) == {"master", "c0", "c1"}
    # served from the local snapshot: not one byte crossed the boundary
    assert plane.fabric.cross_cluster_bytes() == before
    # the same read without a replica is a full round trip
    plain = ManagementPlane(coalesce_watches=True)
    plain.add_cluster("master", is_master=True)
    plain.add_cluster("c0")
    plain.tick(n=2)
    assert plain.shipper is None and plain.agents["c0"].replica is None
    b0 = plain.fabric.cross_cluster_bytes()
    plain.agents["c0"].fleet_telemetry(max_lag=2.0)
    assert plain.fabric.cross_cluster_bytes() > b0


def test_byte_ledger_reflects_ships_not_reads():
    """Satellite: cross_bytes under fan-out is the shipped batches, however
    many reads each cluster issues."""
    plane = _fanout_plane()
    agent = plane.agents["c0"]
    ships_before = dict(plane.shipper.stats)
    cross_before = plane.fabric.cross_cluster_bytes()
    for _ in range(50):
        agent.fleet_telemetry(max_lag=5.0)
        agent.queue_depths(max_lag=5.0)
    assert plane.fabric.cross_cluster_bytes() == cross_before   # reads: free
    plane.tick()                        # the sweep ships one envelope/cluster
    shipped = (plane.shipper.stats["shipped_bytes"]
               - ships_before.get("shipped_bytes", 0))
    grown = plane.fabric.cross_cluster_bytes() - cross_before
    assert shipped > 0
    # everything the read path added to the ledger is ship traffic (the rest
    # of the growth is heartbeat/lease chatter, which exists in both modes)
    assert grown >= shipped


def test_fanout_works_with_synchronous_watches_too():
    """The shipper buffers per-event callbacks the same way it buffers
    coalesced batches — fan-out is delivery-mode independent."""
    plane = _fanout_plane(coalesce=False)
    agent = plane.agents["c0"]
    plane.overwatch.handle({"op": "put", "key": "/queues/sync-q",
                            "value": {"ready": 3, "inflight": 0}})
    plane.tick()
    before = plane.fabric.cross_cluster_bytes()
    assert agent.queue_depths(max_lag=2.0)["sync-q"]["ready"] == 3
    assert plane.fabric.cross_cluster_bytes() == before


def test_replica_covers_only_shipped_prefixes():
    plane = _fanout_plane()
    agent = plane.agents["c0"]
    rep = agent.replica
    assert rep.covers("/telemetry/") and rep.covers("/queues/q1")
    assert not rep.covers("/jobs/") and not rep.covers("/tele")
    # an uncovered prefix falls through to the primary round-trip
    before = plane.fabric.cross_cluster_bytes()
    agent.ow.range_stale("/jobs/", max_lag=100.0)
    assert plane.fabric.cross_cluster_bytes() > before


# ------------------------------------------------------------- fault paths
def test_channel_death_stale_within_bound_then_primary_fallback():
    plane = _fanout_plane()
    agent = plane.agents["c0"]
    # kill the master->c0 dispatch relay the ships ride
    relay = plane.dispatcher._relays[("dispatch-relay", "c0")]
    ch = plane.fabric.channel_at("master", relay)
    plane.fabric.kill_channel(ch.channel_id)
    # a new value lands on the primary; ships can no longer deliver it
    plane.overwatch.handle({"op": "put", "key": "/queues/hot",
                            "value": {"ready": 7, "inflight": 0}})
    fails_before = plane.shipper.stats["ship_failures"]
    plane.tick()
    assert plane.shipper.stats["ship_failures"] > fails_before
    # within bound: the replica serves the (stale) pre-death snapshot locally
    assert "hot" not in agent.queue_depths(max_lag=5.0)
    # past bound: transparent fallback to the primary — never silently staler
    plane.tick(n=6)
    depths = agent.queue_depths(max_lag=2.0)
    assert depths["hot"]["ready"] == 7
    # heal: the next ship carries the missed delta, reads go local again
    plane.fabric.revive_channel(ch.channel_id)
    plane.tick()
    assert agent.replica.get("/queues/hot")["ready"] == 7
    cross = plane.fabric.cross_cluster_bytes()
    assert agent.queue_depths(max_lag=2.0)["hot"]["ready"] == 7
    assert plane.fabric.cross_cluster_bytes() == cross


def test_partition_heal_resumes_from_cumulative_ack():
    plane = _fanout_plane()
    agent = plane.agents["c0"]
    rev_before = agent.replica.applied_rev
    plane.fabric.partition_cluster("c0")
    # several sweeps' worth of deltas accumulate while the cluster is dark
    # (heal before the lease TTL so the cluster is never tombstoned)
    for k in range(3):
        plane.overwatch.handle({"op": "put", "key": f"/queues/q{k}",
                                "value": {"ready": k + 1, "inflight": 0}})
        if k:
            plane.tick()
    assert agent.replica.applied_rev == rev_before      # nothing landed
    plane.fabric.heal_cluster("c0")
    plane.tick()
    # ONE ship after heal converges the replica on everything it missed
    for k in range(3):
        assert agent.replica.get(f"/queues/q{k}") == {"ready": k + 1,
                                                      "inflight": 0}
    assert agent.replica.applied_rev >= rev_before + 3
    primary = plane.overwatch.handle(
        {"op": "range", "prefix": "/queues/"})["items"]
    local = agent.ow.range_stale("/queues/", max_lag=2.0)
    assert local == primary


def test_cluster_death_unregisters_feed():
    plane = _fanout_plane()
    assert "c0" in plane.shipper._feeds
    plane.fabric.partition_cluster("c0")
    plane.tick(n=8)                      # lease expires, tombstone lands
    assert "c0" not in plane.dispatcher.clusters()
    assert "c0" not in plane.shipper._feeds
    assert "c1" in plane.shipper._feeds  # survivors keep their feed


def test_ship_never_advances_horizon_past_pending_events():
    """Regression: shipping while coalesced watch events are still pending
    must not stamp an ack horizon beyond them — ship_all takes the watch
    barrier, and the horizon only moves to ingested revisions, so the put
    below can never be skipped by later ships."""
    plane = _fanout_plane()
    agent = plane.agents["c0"]
    plane.overwatch.handle({"op": "put", "key": "/queues/hot",
                            "value": {"ready": 9, "inflight": 0}})
    # no sweep between the put and this direct ship: the event sits in the
    # coalesced queue until ship_all's own barrier delivers it
    plane.shipper.ship_all()
    assert agent.replica.get("/queues/hot") == {"ready": 9, "inflight": 0}
    plane.tick(n=2)
    assert agent.queue_depths(max_lag=2.0)["hot"]["ready"] == 9


def test_replica_never_synced_has_infinite_lag():
    rep = LocalReplica(REPLICA_PREFIXES)
    assert rep.lag(0.0) == float("inf")
    rep.apply_ship({"events": [("put", "/queues/a", {"ready": 1}, 5)],
                    "rev": 5, "clock": 3.0})
    assert rep.lag(3.0) == 0.0 and rep.applied_rev == 5
    # idempotent cumulative redelivery converges without deduplication
    rep.apply_ship({"events": [("put", "/queues/a", {"ready": 1}, 5),
                               ("delete", "/queues/a", None, 6)],
                    "rev": 6, "clock": 4.0})
    assert rep.get("/queues/a") is None and rep.applied_rev == 6


# ---------------------------------------------------------- worker depth gate
def test_depth_gated_worker_skips_empty_pulls_and_completes():
    plane = ManagementPlane(coalesce_watches=True, replica_fanout=True)
    plane.add_cluster("master", is_master=True,
                      local_plane=SimLocalPlane(caps=("control",)))
    plane.add_cluster("onprem", local_plane=SimLocalPlane(caps=("cpu",)))
    comp = HybridComposer(plane, {"onprem": ["w0"]},
                          worker_queues={"w0": ("default", "idle-q")},
                          depth_gated_workers=True)
    comp.add_dag(DAG("d", [Task(f"t{i}", kind="python") for i in range(5)]))
    assert comp.run_dag("d", max_ticks=60)
    w = comp.workers[0]
    assert w.executed == 5
    # the never-populated queue (and pre-publication ticks) cost no pulls
    assert w.skipped_pulls > 0
    # master-local workers never gate (their pulls never cross the boundary)
    assert comp._depth_hint_for(plane.agents["master"]) is None


def test_locality_bench_reduction_clears_bar_at_small_scale():
    """The benchmark's own gate, pinned at the cheap 8-cluster point: byte
    counts are deterministic, so this is a real assertion, not a flake."""
    from benchmarks.control_plane import bench_locality_point
    baseline = bench_locality_point(8, fanout=False, ticks=4)
    fanout = bench_locality_point(8, fanout=True, ticks=4)
    assert baseline["reads"] == fanout["reads"] > 0
    reduction = (baseline["cross_bytes_per_read"]
                 / fanout["cross_bytes_per_read"])
    assert reduction >= 5.0


# ------------------------------------------------------- fabric fast path
def test_acl_exempt_prefix_scans_once_per_source():
    acl = AclTable()
    acl.allow("pod-a", ("ip", 1))
    scans0 = acl.stats["prefix_scans"]
    for _ in range(20):
        assert acl.allowed("pod-a", ("ip", 1))
        assert acl.allowed("gw@c1", ("ip", 9))      # exempt infra id
        assert not acl.allowed("intruder", ("ip", 1))
    # one scan per distinct source id, however many sends
    assert acl.stats["prefix_scans"] - scans0 == 2  # gw@c1 + intruder
    # behavior unchanged by memoization: default-deny still bites after
    # block_all, exemption still wins for infra ids
    acl.block_all(("ip", 1))
    assert not acl.allowed("pod-a", ("ip", 1))
    assert acl.allowed("agent@x", ("ip", 1))
    assert acl.allowed("system@dispatcher", ("ip", 1))


def test_byte_caches_evict_incrementally():
    _STR_BYTES_CACHE.clear()
    _str_bytes("hot-entry")
    # push the cache past its limit with one-shot strings
    for i in range(_CACHE_LIMIT + 10):
        _str_bytes(f"cold-{i}")
    # never wiped: the cache sits AT the limit, not at 1 post-clear()
    assert len(_STR_BYTES_CACHE) == _CACHE_LIMIT
    assert _str_bytes("x" * 33) == 33               # still correct
    _STR_BYTES_CACHE.clear()                        # leave no test residue


def test_message_log_limit_zero_skips_append():
    fabric = Fabric(message_log_limit=0)
    fabric.register_handler("c", ("ip", 1), lambda p: {"ok": True})
    for _ in range(5):
        assert fabric.send("c", "pod", "c", ("ip", 1), {"x": 1})["ok"]
    assert len(fabric.message_log) == 0
    assert fabric.message_log.total_appended == 0   # never even constructed
    # request byte accounting is unaffected by the skip (local round trips
    # charge the request only; responses are sized on channel paths)
    assert fabric.local_bytes["c"] == 5 * _payload_bytes({"x": 1})


def test_response_bytes_cross_the_boundary_too():
    """A fat response to a thin request is cross-boundary traffic — the
    asymmetry the locality benchmark's bytes/read baseline measures."""
    plane = ManagementPlane()
    plane.add_cluster("master", is_master=True)
    plane.add_cluster("c0")
    for i in range(50):
        plane.overwatch.handle({"op": "put", "key": f"/telemetry/f{i}",
                                "value": {"load": float(i)}})
    req_bytes = _payload_bytes({"op": "range", "prefix": "/telemetry/"})
    before = plane.fabric.cross_cluster_bytes()
    items = plane.agents["c0"].ow.range("/telemetry/")
    assert len(items) == 50
    # the 50-row response dwarfs the request on the ledger
    assert plane.fabric.cross_cluster_bytes() - before > 3 * req_bytes


def test_partitioned_send_still_raises():
    fabric = Fabric()
    fabric.register_handler("c", ("ip", 1), lambda p: {"ok": True})
    fabric.partition_cluster("c")
    with pytest.raises(DeliveryError):
        fabric.send("c", "pod", "c", ("ip", 1), {"x": 1})


# ------------------------------------------------------- replica watch plane
def test_replica_watch_delivers_shipped_events_in_revision_order():
    plane = _fanout_plane()
    agent = plane.agents["c0"]
    seen, batches = [], []
    agent.watch_local("/queues/", lambda e, k, v, r: seen.append((e, k, r)))
    agent.watch_local("/queues/", batches.append, batch=True)
    for k in range(3):
        plane.overwatch.handle({"op": "put", "key": f"/queues/q{k}",
                                "value": {"ready": k, "inflight": 0}})
    plane.tick()
    plane.overwatch.handle({"op": "delete", "key": "/queues/q1"})
    plane.tick()
    assert [e for e, _, _ in seen] == ["put", "put", "put", "delete"]
    assert [k for _, k, _ in seen] == ["/queues/q0", "/queues/q1",
                                      "/queues/q2", "/queues/q1"]
    revs = [r for _, _, r in seen]
    assert revs == sorted(revs)
    # the batch subscriber saw the same events, coalesced per sweep
    assert [len(b) for b in batches] == [3, 1]
    # a prefix outside the shipped set is refused loudly, not silently dead
    with pytest.raises(ValueError):
        agent.watch_local("/jobs/", lambda *a: None)


def test_n_watchers_cost_the_same_cross_bytes_as_zero():
    """The tentpole claim, ledger-verified: feeding 8 watchers per cluster
    is byte-identical to feeding none — the one shipped envelope per sweep
    IS the notify path."""
    def run(watchers):
        plane = _fanout_plane()
        delivered = [0]
        if watchers:
            for name in ("c0", "c1"):
                for _ in range(watchers):
                    plane.agents[name].watch_local(
                        "/queues/",
                        lambda evs: delivered.__setitem__(
                            0, delivered[0] + len(evs)),
                        batch=True)
        base = plane.fabric.cross_cluster_bytes()
        for t in range(4):
            plane.overwatch.handle({"op": "put", "key": "/queues/hot",
                                    "value": {"ready": t, "inflight": 0}})
            plane.tick()
        return plane.fabric.cross_cluster_bytes() - base, delivered[0]

    bytes_zero, _ = run(0)
    bytes_eight, delivered = run(8)
    assert bytes_eight == bytes_zero
    assert delivered == 2 * 8 * 4        # every watcher saw every churn


def test_watch_dedupes_cumulative_redelivery():
    """Exactly-once notify: re-applying an envelope whose ack was lost
    re-converges the snapshot but never re-fires watchers."""
    rep = LocalReplica(REPLICA_PREFIXES)
    seen = []
    rep.watch("/queues/", lambda e, k, v, r: seen.append((e, k, r)))
    batch = {"events": [("put", "/queues/a", {"ready": 1}, 5),
                        ("delete", "/queues/b", None, 6)],
             "rev": 6, "clock": 1.0}
    rep.apply_ship(batch)
    rep.apply_ship(dict(batch, clock=2.0))           # redelivered verbatim
    assert seen == [("put", "/queues/a", 5), ("delete", "/queues/b", 6)]
    # genuinely new events still flow
    rep.apply_ship({"events": [("put", "/queues/a", {"ready": 2}, 7)],
                    "rev": 7, "clock": 3.0})
    assert seen[-1] == ("put", "/queues/a", 7) and len(seen) == 3


def test_watcher_queue_is_bounded_and_raising_callback_retries():
    """Satellite: a stuck callback keeps (bounded) state, not unbounded
    memory — RingLog discipline with a drop counter in stats — and a
    callback that heals gets the retained events on the next ship."""
    rep = LocalReplica(REPLICA_PREFIXES, watch_queue_limit=4)
    delivered, broken = [], [True]

    def cb(e, k, v, r):
        if broken[0]:
            raise RuntimeError("stuck")
        delivered.append((e, k, r))

    w = rep.watch("/queues/", cb)
    for i in range(10):
        rep.apply_ship({"events": [("put", f"/queues/q{i}", {"r": i}, i + 1)],
                        "rev": i + 1, "clock": float(i)})
    assert len(w.pending) == 4                       # capped, not 10
    assert w.dropped == 6
    assert rep.stats["watch_dropped"] == 6
    assert rep.stats["watch_errors"] > 0
    broken[0] = False
    # an empty freshness beacon drains the retained queue
    rep.apply_ship({"events": [], "rev": 10, "clock": 10.0})
    assert [k for _, k, _ in delivered] == [f"/queues/q{i}"
                                            for i in range(6, 10)]
    assert not w.pending


def test_reset_batch_diffs_against_snapshot_tombstones_included():
    """Crash-recovery replay: a reset batch must resynthesize watcher state
    — a tombstone for the key deleted during the gap, a put only for the key
    that changed, SILENCE for the key the watcher already holds."""
    rep = LocalReplica(REPLICA_PREFIXES)
    rep.apply_ship({"events": [("put", "/queues/keep", {"ready": 1}, 1),
                               ("put", "/queues/gone", {"ready": 2}, 2),
                               ("put", "/queues/chg", {"ready": 3}, 3)],
                    "rev": 3, "clock": 1.0})
    seen = []
    rep.watch("/queues/", lambda e, k, v, r: seen.append((e, k, v)))
    rep.apply_ship({"events": [("put", "/queues/keep", {"ready": 1}, 10),
                               ("put", "/queues/chg", {"ready": 9}, 11),
                               ("put", "/queues/new", {"ready": 4}, 12)],
                    "rev": 12, "clock": 5.0, "reset": True})
    assert ("delete", "/queues/gone", None) in seen
    assert ("put", "/queues/chg", {"ready": 9}) in seen
    assert ("put", "/queues/new", {"ready": 4}) in seen
    assert not any(k == "/queues/keep" for _, k, _ in seen)   # no duplicate
    assert len(seen) == 3
    assert rep.get("/queues/gone") is None
    assert rep.stats["resets"] == 1


def test_duplicate_register_keeps_horizon_and_never_reships_seed():
    """Satellite regression (the retry race): a duplicate register for a
    live feed — an agent retrying after a timed-out ack — must neither
    re-ship the bootstrap seed nor reset the cumulative-ack horizon."""
    plane = _fanout_plane()
    feed = plane.shipper._feeds["c0"]
    horizon = feed.acked_rev
    assert not feed.seed                 # bootstrap already confirmed
    events_before = plane.agents["c0"].replica.stats["events"]
    plane.shipper.register("c0")         # the retry
    assert plane.shipper.stats["duplicate_registers"] == 1
    assert plane.shipper._feeds["c0"] is feed
    assert feed.acked_rev == horizon and not feed.seed and not feed.reset
    plane.tick()
    # the next ship carried only churn (telemetry beacons), not a re-seed
    # of the whole directory: the replica saw no snapshot-sized event burst
    assert (plane.agents["c0"].replica.stats["events"]
            - events_before) <= 2 * len(plane.agents)


def test_cluster_local_read_service_endpoint():
    """The replica as a service endpoint: pods dial their OWN agent's
    REPLICA_PORT for reads and watch registration — zero cross-boundary
    bytes for both."""
    plane = _fanout_plane()
    agent = plane.agents["c0"]
    plane.overwatch.handle({"op": "put", "key": "/queues/svc-q",
                            "value": {"ready": 5, "inflight": 0}})
    plane.tick()
    before = plane.fabric.cross_cluster_bytes()
    resp = plane.fabric.send("c0", "w0", "c0", agent.replica_addr,
                             {"op": "range_stale", "prefix": "/queues/",
                              "max_lag": 2.0})
    assert resp["ok"] and resp["items"]["/queues/svc-q"]["ready"] == 5
    got = []
    resp = plane.fabric.send("c0", "w0", "c0", agent.replica_addr,
                             {"op": "watch_batch", "prefix": "/queues/",
                              "cb": got.append})
    assert resp["ok"]
    assert plane.fabric.cross_cluster_bytes() == before   # all local
    plane.overwatch.handle({"op": "put", "key": "/queues/svc-q",
                            "value": {"ready": 6, "inflight": 0}})
    plane.tick()
    assert any(k == "/queues/svc-q" for _, k, _, _ in got[-1])
    # unknown ops are refused, not crashed
    assert not plane.fabric.send("c0", "w0", "c0", agent.replica_addr,
                                 {"op": "bogus"})["ok"]


def test_fallback_reads_counted_separately():
    """Satellite: a primary fallback past the staleness bound is a named
    counter, not an anonymous byte blob."""
    plane = _fanout_plane()
    agent = plane.agents["c0"]
    assert plane.fabric.stats["fallback_reads"] == 0
    agent.queue_depths(max_lag=2.0)                  # replica-local
    assert plane.fabric.stats["fallback_reads"] == 0
    relay = plane.dispatcher._relays[("dispatch-relay", "c0")]
    ch = plane.fabric.channel_at("master", relay)
    plane.fabric.kill_channel(ch.channel_id)
    plane.tick(n=4)                                  # replica goes stale
    agent.queue_depths(max_lag=2.0)                  # forced primary trip
    assert plane.fabric.stats["fallback_reads"] == 1
    # an uncovered prefix is a deliberate primary read, NOT a fallback
    agent.ow.range_stale("/jobs/", max_lag=100.0)
    assert plane.fabric.stats["fallback_reads"] == 1


def test_local_view_materializes_from_watch_plane():
    plane = _fanout_plane()
    agent = plane.agents["c0"]
    view = agent.local_view("/queues/")
    assert agent.local_view("/queues/") is view      # cached
    plane.overwatch.handle({"op": "put", "key": "/queues/vq",
                            "value": {"ready": 2, "inflight": 0}})
    plane.tick()
    assert view.get("/queues/vq")["ready"] == 2
    assert view.fresh(plane.fabric.clock, 2.0)
    plane.overwatch.handle({"op": "delete", "key": "/queues/vq"})
    plane.tick()
    assert view.get("/queues/vq") is None
    # the view always mirrors the primary directory exactly
    primary = plane.overwatch.handle(
        {"op": "range", "prefix": "/queues/"})["items"]
    assert view.items() == primary


def test_fleet_watch_observes_autoscale_state_locally():
    from repro.autoscale.reconciler import Reconciler
    plane = _fanout_plane()
    agent = plane.agents["c0"]
    seen = []
    Reconciler.fleet_watch(agent, "f", lambda e, k, v, r: seen.append(v))
    plane.overwatch.handle({"op": "put", "key": "/autoscale/f",
                            "value": {"desired": 3, "replicas": 1}})
    before = plane.fabric.cross_cluster_bytes()
    ships_before = plane.shipper.stats["shipped_bytes"]
    plane.tick()
    shipped = plane.shipper.stats["shipped_bytes"] - ships_before
    assert seen and seen[-1]["desired"] == 3
    assert agent.fleet_states(max_lag=2.0)["f"]["replicas"] == 1
    # the only cross-boundary traffic carrying the observation is the ships
    # (plus heartbeat chatter) — nothing per-observer
    assert plane.fabric.cross_cluster_bytes() - before >= shipped > 0


def test_notify_bench_reduction_clears_bar_and_is_o1_in_watchers():
    """The notify gate pinned at the cheap 8-cluster point, plus the O(1)
    evidence: shipped bytes at 1 and 8 watchers per cluster are EQUAL."""
    from benchmarks.control_plane import bench_notify_point
    baseline = bench_notify_point(8, fanout=False, ticks=4)
    fanout = bench_notify_point(8, fanout=True, ticks=4)
    assert baseline["events_delivered"] == fanout["events_delivered"] > 0
    reduction = (baseline["cross_bytes_per_event"]
                 / fanout["cross_bytes_per_event"])
    assert reduction >= 5.0
    one = bench_notify_point(8, fanout=True, ticks=4, watchers=1)
    assert one["cross_bytes"] == fanout["cross_bytes"]
    assert fanout["fallback_reads"] == 0 and fanout["ok"]
