"""Paper §4 Algorithms 1-5: discovery, connectivity, access control.

The hypothesis test at the bottom is the paper's core invariant, checked over
random Pod-Service graphs and partitions:
  every pod with f[p,s]=1 reaches s BY NAME from its own partition;
  every pod with f[p,s]=0 is denied — regardless of where s lives.
"""
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.plane import ManagementPlane
from repro.core.service_graph import AppSpec, Pod, Service
from repro.core.transport import DeliveryError
from repro.pipelines.services import ServiceClient, ServiceEndpoint


def build_spec(master_hosts=True):
    """broker on master, db on onprem-a; consumers spread across clusters."""
    services = (Service("broker", 6379, ("broker-pod",)),
                Service("db", 5432, ("db-pod",)))
    pods = (Pod("broker-pod", ()), Pod("db-pod", ()),
            Pod("worker-pub", ("broker", "db")),
            Pod("worker-priv", ("broker", "db")),
            Pod("rogue", ()))
    partition = {"broker-pod": "master",
                 "db-pod": "onprem-a",
                 "worker-pub": "master",
                 "worker-priv": "onprem-b",
                 "rogue": "onprem-b"}
    return AppSpec(services, pods, partition)


@pytest.fixture
def configured(plane):
    spec = build_spec()
    plane.upload_spec(spec)
    # register echo handlers where each service actually lives
    for svc in ("broker", "db"):
        host = spec.host_cluster(svc)
        ServiceEndpoint(plane.fabric, spec, plane.agents[host].state, svc,
                        lambda m, _s=svc: {"ok": True, "svc": _s,
                                           "echo": m.get("x")})
    return plane, spec


def client(plane, spec, pod):
    cluster = spec.partition[pod]
    return ServiceClient(plane.fabric, plane.agents[cluster].state, pod)


# ------------------------------------------------------------------ Algorithm 1
def test_dns_native_vs_dummy(configured):
    plane, spec = configured
    master = plane.agents["master"].state
    priv = plane.agents["onprem-b"].state
    # broker hosted on master: real IP there, dummy elsewhere
    assert master.dns["broker"][0].startswith("10.0.1.")
    assert priv.dns["broker"][0].startswith(f"10.{priv.idx}.2.")
    # every cluster resolves every service name
    for ag in plane.agents.values():
        assert set(ag.state.dns) == {"broker", "db"}


# ------------------------------------------------------------------ Algorithm 2
def test_port_determinism(configured):
    plane, spec = configured
    # sorted-rank ports: identical eport/iport tables in every cluster
    eports = {c: ag.state.eport for c, ag in plane.agents.items()}
    for svc in ("broker", "db"):
        ports = {t[svc] for t in eports.values() if svc in t}
        assert len(ports) <= 1


# --------------------------------------------------------------- reachability
def test_pod_reaches_service_cross_cloud(configured):
    plane, spec = configured
    # private worker -> master-hosted broker (Figure 2 path)
    resp = client(plane, spec, "worker-priv").call("broker", {"x": 42})
    assert resp == {"ok": True, "svc": "broker", "echo": 42}
    # private worker -> other-private-hosted db (hub relay path)
    resp = client(plane, spec, "worker-priv").call("db", {"x": 7})
    assert resp["svc"] == "db"
    # public worker -> private db
    resp = client(plane, spec, "worker-pub").call("db", {"x": 1})
    assert resp["svc"] == "db"


def test_traffic_crosses_boundary_only_when_needed(configured):
    plane, spec = configured
    before = plane.fabric.cross_cluster_bytes()
    # master-local call: worker-pub -> broker (both on master)
    client(plane, spec, "worker-pub").call("broker", {"x": 0})
    assert plane.fabric.cross_cluster_bytes() == before
    # cross call bumps the ledger
    client(plane, spec, "worker-priv").call("broker", {"x": 0})
    assert plane.fabric.cross_cluster_bytes() > before


# ------------------------------------------------------------------ Algorithm 3
def test_access_control_default_deny(configured):
    plane, spec = configured
    with pytest.raises(DeliveryError):
        client(plane, spec, "rogue").call("broker", {"x": 1})
    with pytest.raises(DeliveryError):
        client(plane, spec, "rogue").call("db", {"x": 1})


def test_acl_audit_covers_expected_flows(configured):
    plane, spec = configured
    from repro.core.access_control import audit
    for ag in plane.agents.values():
        assert audit(spec, ag.state) == []


# ------------------------------------------------- the paper invariant (property)
@st.composite
def app_specs(draw):
    n_clusters = draw(st.integers(2, 4))
    clusters = [f"c{i}" for i in range(n_clusters)]   # c0 = master
    n_services = draw(st.integers(1, 4))
    n_consumers = draw(st.integers(1, 5))
    services, pods, partition = [], [], {}
    for s in range(n_services):
        back = f"back{s}"
        host = clusters[draw(st.integers(0, n_clusters - 1))]
        services.append(Service(f"svc{s}", 7000 + s, (back,)))
        pods.append(Pod(back, ()))
        partition[back] = host
    svc_names = [s.name for s in services]
    for c in range(n_consumers):
        needs = tuple(sorted(draw(st.sets(st.sampled_from(svc_names),
                                          max_size=len(svc_names)))))
        pods.append(Pod(f"pod{c}", needs))
        partition[f"pod{c}"] = clusters[draw(st.integers(0, n_clusters - 1))]
    return clusters, AppSpec(tuple(services), tuple(pods), partition)


@settings(max_examples=25, deadline=None)
@given(app_specs())
def test_fps_invariant(spec_case):
    clusters, spec = spec_case
    plane = ManagementPlane(master="c0")
    plane.add_cluster("c0", is_master=True)
    for c in clusters[1:]:
        plane.add_cluster(c)
    plane.upload_spec(spec)
    for svc in spec.services:
        host = spec.host_cluster(svc.name)
        ServiceEndpoint(plane.fabric, spec, plane.agents[host].state,
                        svc.name, lambda m, _s=svc.name: {"svc": _s})
    for pod in spec.pods:
        cl = ServiceClient(plane.fabric,
                           plane.agents[spec.partition[pod.name]].state,
                           pod.name)
        for svc in spec.services:
            if svc.name in pod.needs:
                assert cl.call(svc.name, {})["svc"] == svc.name
            else:
                with pytest.raises(DeliveryError):
                    cl.call(svc.name, {})
