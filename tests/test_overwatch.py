"""Overwatch: strongly-consistent store semantics (paper §2.iii)."""
import pytest

from tests.conftest import make_plane


def test_put_get_delete_range(plane):
    ow = plane.agents["onprem-a"].ow
    r1 = ow.put("/a/x", 1)
    r2 = ow.put("/a/y", {"v": 2})
    assert r2 > r1                        # revisions are monotone
    assert ow.get("/a/x") == 1
    assert ow.range("/a/") == {"/a/x": 1, "/a/y": {"v": 2}}
    ow.delete("/a/x")
    assert ow.get("/a/x") is None


def test_cas_linearizable(plane):
    ow_a = plane.agents["onprem-a"].ow
    ow_b = plane.agents["onprem-b"].ow
    rev = ow_a.put("/cfg", "v0")
    assert ow_b.cas("/cfg", "v1", expect_revision=rev)
    assert not ow_a.cas("/cfg", "v2", expect_revision=rev)  # stale revision
    assert ow_a.get("/cfg") == "v1"


def test_op_log_is_total_order(plane):
    ow = plane.agents["master"].ow
    for i in range(5):
        ow.put(f"/log/{i}", i)
    log = plane.overwatch.op_log
    revs = [r for r, *_ in log]
    assert revs == sorted(revs) and len(set(revs)) == len(revs)


def test_lease_expiry_deletes_keys_and_notifies():
    plane = make_plane(1)
    ow = plane.agents["onprem-0"].ow
    events = []
    plane.overwatch.watch("/svc/", lambda *a: events.append(a))
    lease = ow.lease_grant(ttl=2.0)
    ow.put("/svc/ephemeral", "x", lease=lease)
    plane.tick(n=1)
    assert ow.get("/svc/ephemeral") == "x"
    plane.tick(n=5)                        # lease expires, no keepalive
    assert ow.get("/svc/ephemeral") is None
    assert any(e[0] == "delete" for e in events)


def test_keepalive_sustains_lease():
    plane = make_plane(1)
    ow = plane.agents["onprem-0"].ow
    lease = ow.lease_grant(ttl=2.0)
    ow.put("/svc/alive", 1, lease=lease)
    for _ in range(6):
        plane.tick()
        ow.lease_keepalive(lease)
    assert ow.get("/svc/alive") == 1


def test_cluster_registration_is_lease_backed(plane):
    assert plane.overwatch.handle(
        {"op": "get", "key": "/clusters/onprem-a"})["value"]["idx"] >= 1
    plane.fabric.partition_cluster("onprem-a")
    plane.tick(n=8)                        # heartbeats fail -> lease expires
    assert plane.overwatch.handle(
        {"op": "get", "key": "/clusters/onprem-a"})["value"] is None
    # master + onprem-b still registered
    assert plane.overwatch.handle(
        {"op": "get", "key": "/clusters/onprem-b"})["value"] is not None
