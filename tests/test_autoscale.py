"""Elastic autoscaling plane: policy hysteresis, queue-depth-driven
reconciliation, quota/spillover placement, and the loss-free worker drain
protocol (plus the broker/composer satellites that ride along)."""
from collections import Counter

import pytest

from repro.autoscale import ScalingPolicy
from repro.core.plane import ManagementPlane, SimLocalPlane
from repro.core.transport import DeliveryError
from repro.pipelines import DAG, Task, HybridComposer
from repro.pipelines.broker import Broker
from repro.pipelines.taskdb import TaskDB
from repro.pipelines.worker import PipelineWorker


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


class LocalClient:
    """In-process broker+taskdb behind the ServiceClient interface."""

    def __init__(self, broker: Broker, db: TaskDB):
        self.broker = broker
        self.db = db
        self.calls = Counter()

    def call(self, service, msg):
        self.calls[(service, msg["op"])] += 1
        return (self.broker.handle if service == "broker"
                else self.db.handle)(msg)


# ------------------------------------------------------------------- policy
def test_policy_cold_start_and_step_limit():
    p = ScalingPolicy(family="f", target_depth_per_worker=10,
                      max_replicas=16, scale_up_step=4)
    assert p.desired_replicas(0, 0) == 0
    assert p.desired_replicas(15, 0) == 2          # cold start: ceil(15/10)
    assert p.desired_replicas(1000, 0) == 4        # step-limited
    assert p.desired_replicas(1000, 4) == 8        # keeps stepping
    assert p.desired_replicas(1000, 14) == 16      # clamped at max


def test_policy_hysteresis_band_is_sticky():
    p = ScalingPolicy(family="f", target_depth_per_worker=8,
                      up_threshold=1.25, down_threshold=0.5, max_replicas=8)
    # 2 workers, target band is (2*8*0.5, 2*8*1.25] = (8, 20]
    assert p.desired_replicas(18, 2) == 2          # inside band: no change
    assert p.desired_replicas(9, 2) == 2
    assert p.desired_replicas(21, 2) == 3          # above band: grow
    assert p.desired_replicas(7, 2) == 1           # below band: shrink


def test_policy_scale_to_zero_and_min_floor():
    p = ScalingPolicy(family="f", target_depth_per_worker=8, min_replicas=0,
                      scale_down_step=2, max_replicas=8)
    assert p.desired_replicas(0, 3) == 1
    assert p.desired_replicas(0, 1) == 0           # scale-to-zero allowed
    floor = ScalingPolicy(family="g", min_replicas=2, max_replicas=8,
                          scale_down_step=4)
    assert floor.desired_replicas(0, 4) == 2       # never below the floor
    assert floor.desired_replicas(0, 0) == 2       # cold start to the floor
    # a fleet knocked below the floor (lost pod) recovers even when the
    # backlog is too quiet to clear the up-hysteresis band
    assert floor.desired_replicas(0, 1) == 2


def test_policy_down_threshold_zero_still_scales_to_zero():
    p = ScalingPolicy(family="f", target_depth_per_worker=8,
                      down_threshold=0.0, scale_down_step=8, max_replicas=8)
    assert p.desired_replicas(1, 8) == 8     # any backlog holds the fleet
    assert p.desired_replicas(0, 8) == 0     # an empty one drains it


def test_policy_validation():
    with pytest.raises(ValueError):
        ScalingPolicy(family="f", up_threshold=0.9)
    with pytest.raises(ValueError):
        ScalingPolicy(family="f", down_threshold=1.5)
    with pytest.raises(ValueError):
        ScalingPolicy(family="f", min_replicas=5, max_replicas=2)
    with pytest.raises(ValueError):
        ScalingPolicy(family="f", target_depth_per_worker=0)


# ---------------------------------------------------------- broker satellites
def test_probing_unknown_queue_creates_no_state():
    b = Broker()
    b.handle({"op": "pull", "queue": "ghost"})
    b.handle({"op": "pull_many", "queue": "ghost", "max_n": 8})
    d = b.handle({"op": "depth", "queue": "ghost"})
    assert (d["ready"], d["inflight"]) == (0, 0)
    many = b.handle({"op": "depth_many", "queues": ["ghost"]})["depths"]
    assert many["ghost"] == {"ready": 0, "inflight": 0}
    assert "ghost" not in b.queues
    assert "ghost" not in b._inflight_count
    assert b.handle({"op": "depth_many"})["depths"] == {}


def test_depth_many_listing_drops_drained_queues():
    b = Broker()
    b.handle({"op": "push", "queue": "q", "msg": {"i": 1}})
    assert "q" in b.handle({"op": "depth_many"})["depths"]
    tag = b.handle({"op": "pull", "queue": "q"})["tag"]
    b.handle({"op": "ack", "tag": tag})
    # fully drained: gone from the listing, still zero when asked explicitly
    assert b.handle({"op": "depth_many"})["depths"] == {}
    assert b.handle({"op": "depth_many", "queues": ["q"]})["depths"]["q"] == \
        {"ready": 0, "inflight": 0}


def test_redelivery_stats_distinguish_expiry_from_nack():
    clock = FakeClock()
    b = Broker(clock_fn=clock, lease=5.0)
    b.handle({"op": "push_many", "queue": "q",
              "msgs": [{"i": i} for i in range(4)]})
    tags = b.handle({"op": "pull_many", "queue": "q", "max_n": 4})["tags"]
    b.handle({"op": "nack", "tag": tags[0]})
    b.handle({"op": "nack_many", "tags": tags[1:3]})
    assert b.stats["redelivered_nacked"] == 3
    assert b.stats.get("redelivered", 0) == 0       # no lease has expired
    clock.t = 6.0
    b.handle({"op": "depth", "queue": "q"})          # expiry sweep
    assert b.stats["redelivered"] == 1               # the un-nacked lease
    assert b.stats["redelivered_nacked"] == 3        # unchanged
    d = b.handle({"op": "depth", "queue": "q"})
    assert (d["ready"], d["inflight"]) == (4, 0)


def test_nack_many_is_idempotent_and_honors_front():
    b = Broker()
    b.handle({"op": "push_many", "queue": "q", "msgs": [{"m": 1}, {"m": 2}]})
    tags = b.handle({"op": "pull_many", "queue": "q", "max_n": 2})["tags"]
    resp = b.handle({"op": "nack_many", "tags": tags + [999],
                     "requeue_front": True})
    assert resp["nacked"] == 2
    assert [m["m"] for m in b.queues["q"]] == [2, 1]  # front, in tag order
    assert b.handle({"op": "nack_many", "tags": tags})["nacked"] == 0


# ------------------------------------------------------- worker drain protocol
def test_drain_commits_inflight_batch_exactly_once():
    """The mid-commit edge: a worker holding a pulled-but-uncommitted batch
    drains — the batch is executed, committed with one upsert_many, final
    acked, and NEVER redelivered."""
    clock = FakeClock()
    broker, db = Broker(clock_fn=clock, lease=10.0), TaskDB()
    client = LocalClient(broker, db)
    broker.handle({"op": "push_many", "queue": "default", "msgs": [
        {"dag": "d", "task": f"t{i}", "kind": "python", "payload": {},
         "try": 1} for i in range(5)]})
    w = PipelineWorker(client, "w0", batch=8, clock_fn=clock)
    assert w.pull_phase() == 5
    assert len(broker.inflight) == 5
    drained = []
    w.on_drained = lambda wk: drained.append(wk.pod)
    client.calls.clear()
    executed = w.drain()
    assert executed == [f"d.t{i}" for i in range(5)]
    assert w.state == "drained" and drained == ["w0"]
    assert client.calls == Counter({("taskdb", "upsert_many"): 1,
                                    ("broker", "ack_many"): 1})
    state = db.handle({"op": "dag_state", "dag": "d"})["tasks"]
    assert all(state[f"t{i}"]["status"] == "success" for i in range(5))
    # far past the lease: nothing redelivers — the final ack beat expiry
    clock.t = 1000.0
    broker.handle({"op": "depth", "queue": "default"})
    assert broker.stats.get("redelivered", 0) == 0
    assert not broker.inflight
    # a drained worker never works again
    broker.handle({"op": "push", "queue": "default",
                   "msg": {"dag": "d", "task": "late", "kind": "python",
                           "payload": {}, "try": 1}})
    assert w.tick() == [] and w.pull_phase() == 0


def test_drain_with_empty_buffer_is_immediate():
    w = PipelineWorker(LocalClient(Broker(), TaskDB()), "w0")
    fired = []
    w.on_drained = lambda wk: fired.append(wk.state)
    assert w.drain() == []
    assert w.state == "drained" and fired == ["drained"]
    # idempotent
    assert w.drain() == [] and len(fired) == 1


def test_draining_worker_stops_pulling_but_tick_finishes():
    broker, db = Broker(), TaskDB()
    client = LocalClient(broker, db)
    broker.handle({"op": "push_many", "queue": "default", "msgs": [
        {"dag": "d", "task": "a", "kind": "python", "payload": {}, "try": 1}]})
    w = PipelineWorker(client, "w0", batch=4)
    w.state = "draining"
    assert w.tick() == []                      # no pull while draining
    assert w.state == "drained"
    d = broker.handle({"op": "depth", "queue": "default"})
    assert d["ready"] == 1                     # the message was never leased


# ------------------------------------------------------- composer tombstones
def test_drained_queue_is_tombstoned_from_depth_view():
    plane = ManagementPlane()
    plane.add_cluster("master", is_master=True)
    comp = HybridComposer(plane, workers={"master": ["w0"]}, worker_batch=4)
    comp.add_dag(DAG("d", [Task(f"t{i}", kind="python") for i in range(12)]))
    comp.tick()
    assert plane.dispatcher.queue_depths()["default"]["ready"] > 0
    for _ in range(8):
        comp.tick()
    assert comp.scheduler.dag_success("d")
    # drained to zero -> key deleted, view entry dropped (not a stale 0/0)
    assert "default" not in plane.dispatcher.queue_depths()
    assert plane.overwatch.handle(
        {"op": "get", "key": "/queues/default"})["value"] is None


def test_queue_drained_within_one_sweep_is_never_published():
    plane = ManagementPlane()
    plane.add_cluster("master", is_master=True)
    comp = HybridComposer(plane, workers={"master": ["w0"]})
    # push + drain between sweeps: no put, no delete for this queue
    comp.broker.handle({"op": "push", "queue": "flash", "msg": {
        "dag": "x", "task": "t", "kind": "python", "payload": {}, "try": 1}})
    tag = comp.broker.handle({"op": "pull", "queue": "flash"})["tag"]
    comp.broker.handle({"op": "ack", "tag": tag})
    comp.publish_queue_depths()
    ops = [(op, key) for _, op, key, _v in plane.overwatch.op_log
           if key.startswith("/queues/")]
    assert ops == []


# ------------------------------------------------------------- the reconciler
def _hybrid_plane():
    plane = ManagementPlane()
    plane.add_cluster("master", is_master=True,
                      local_plane=SimLocalPlane(caps=("control",)))
    plane.add_cluster("onprem-a",
                      local_plane=SimLocalPlane(caps=("cpu", "onprem")))
    plane.add_cluster("cloud-a", local_plane=SimLocalPlane(caps=("cpu",)))
    return plane


def _policy(**kw):
    base = dict(family="default", queues=("default",), requires=("cpu",),
                target_depth_per_worker=8, min_replicas=0, max_replicas=4,
                scale_up_step=4, scale_down_step=2,
                up_cooldown=0.0, down_cooldown=0.0)
    base.update(kw)
    return ScalingPolicy(**base)


def _put_depth(plane, queue, ready, inflight=0):
    plane.overwatch.handle({"op": "put", "key": f"/queues/{queue}",
                            "value": {"ready": ready, "inflight": inflight}})


def test_scale_up_fills_preferred_then_spills_over():
    plane = _hybrid_plane()
    comp = HybridComposer(plane, workers={})
    asc = comp.attach_autoscaler([_policy()],
                                 quotas={"onprem-a": 2, "master": 0},
                                 preferred=("onprem-a",))
    _put_depth(plane, "default", 100)
    asc.reconcile(force=True)
    assert asc.replicas("default") == 4
    placed = Counter(r.cluster for r in asc.pods["default"].values())
    # preferred tier filled to quota, burst spilled into the public cloud
    assert placed == Counter({"onprem-a": 2, "cloud-a": 2})
    state = plane.overwatch.handle(
        {"op": "get", "key": "/autoscale/default"})["value"]
    assert state["replicas"] == 4 and state["at_quota"] is False
    assert set(state["pods"]) == set(asc.pods["default"])


def test_all_clusters_at_quota_blocks_without_crashing():
    plane = _hybrid_plane()
    comp = HybridComposer(plane, workers={})
    asc = comp.attach_autoscaler(
        [_policy(max_replicas=6, scale_up_step=6)],
        quotas={"onprem-a": 1, "cloud-a": 1, "master": 0},
        preferred=("onprem-a",))
    _put_depth(plane, "default", 500)
    asc.reconcile(force=True)
    assert asc.replicas("default") == 2            # capacity, not desire
    state = plane.overwatch.handle(
        {"op": "get", "key": "/autoscale/default"})["value"]
    assert state["at_quota"] is True and state["desired"] == 6
    # freeing quota lets the next pass resume the burst
    asc.quotas["cloud-a"] = 5
    asc.reconcile(force=True)
    assert asc.replicas("default") == 6
    assert plane.overwatch.handle(
        {"op": "get", "key": "/autoscale/default"})["value"]["at_quota"] is False


def test_scale_down_retreats_from_spillover_first_and_revokes_acl():
    plane = _hybrid_plane()
    comp = HybridComposer(plane, workers={})
    asc = comp.attach_autoscaler([_policy()],
                                 quotas={"onprem-a": 2, "master": 0},
                                 preferred=("onprem-a",))
    _put_depth(plane, "default", 100)
    asc.reconcile(force=True)
    cloud_workers = [r.worker for r in asc.pods["default"].values()
                     if r.cluster == "cloud-a"]
    assert asc.replicas("default") == 4 and len(cloud_workers) == 2
    _put_depth(plane, "default", 9)                # below the down band
    asc.reconcile(force=True)
    assert asc.replicas("default") == 2
    remaining = {r.cluster for r in asc.pods["default"].values()}
    assert remaining == {"onprem-a"}               # cloud pods went first
    # the drained pods' jobs are tombstoned from the store (no leaked
    # placement/status keys for elastic churn) and their ACL access is gone
    for w in cloud_workers:
        assert w.state == "drained"
        assert plane.job_status(w.pod) is None
        assert plane.overwatch.handle(
            {"op": "get", "key": f"/jobs/{w.pod}/placement"})["value"] is None
        with pytest.raises(DeliveryError):
            w.client.call("broker", {"op": "depth", "queue": "default"})


def test_blocked_reason_distinguishes_eligibility_from_quota():
    plane = _hybrid_plane()
    comp = HybridComposer(plane, workers={})
    asc = comp.attach_autoscaler([_policy(requires=("gpu",))])
    _put_depth(plane, "default", 100)
    asc.reconcile(force=True)                      # nothing carries "gpu"
    state = plane.overwatch.handle(
        {"op": "get", "key": "/autoscale/default"})["value"]
    assert state["blocked"] == "no_eligible_cluster"
    assert state["at_quota"] is False              # NOT a capacity problem


def test_drain_of_unreachable_pod_is_demoted_to_lost_not_a_crash():
    """A scale-down victim whose cluster partitioned mid-commit: the graceful
    drain fails, the pod is retired in absentia and forgotten, its leases
    are left to redeliver — and the tick loop never sees the exception."""
    plane = _hybrid_plane()
    comp = HybridComposer(plane, workers={}, worker_batch=8)
    asc = comp.attach_autoscaler(
        [_policy(max_replicas=2, scale_up_step=2, scale_down_step=2)],
        quotas={"onprem-a": 1, "cloud-a": 1, "master": 0},
        preferred=("onprem-a",))
    _put_depth(plane, "default", 100)
    asc.reconcile(force=True)
    assert asc.replicas("default") == 2
    cloud = [r for r in asc.pods["default"].values()
             if r.cluster == "cloud-a"][0]
    comp.broker.handle({"op": "push_many", "queue": "default", "msgs": [
        {"dag": "d", "task": f"t{i}", "kind": "python", "payload": {},
         "try": 1} for i in range(3)]})
    assert cloud.worker.pull_phase() == 3          # leased, uncommitted
    plane.fabric.partition_cluster("cloud-a")
    _put_depth(plane, "default", 0)
    asc.reconcile(force=True)                      # must not raise
    assert asc.replicas("default") == 0
    assert any(e[2] == "lost" and e[3] == cloud.name for e in asc.events)
    # the failed drain left its leases to the broker's expiry machinery
    assert len(comp.broker.inflight) == 3


def test_cooldowns_rate_limit_scaling():
    plane = _hybrid_plane()
    comp = HybridComposer(plane, workers={})
    asc = comp.attach_autoscaler(
        [_policy(scale_up_step=1, up_cooldown=5.0)], quotas={"master": 0})
    _put_depth(plane, "default", 100)
    asc.reconcile(force=True)
    assert asc.replicas("default") == 1
    plane.tick()                                    # clock 1 < cooldown 5
    asc.reconcile(force=True)
    assert asc.replicas("default") == 1             # still cooling down
    plane.tick(n=5)
    asc.reconcile(force=True)
    assert asc.replicas("default") == 2
    # cold start bypasses the up-cooldown: a fresh family reacts immediately
    state = asc.pods["default"]
    assert all(r.state == "running" for r in state.values())


def test_scale_to_zero_then_cold_start():
    plane = _hybrid_plane()
    comp = HybridComposer(plane, workers={}, worker_batch=8)
    asc = comp.attach_autoscaler(
        [_policy(max_replicas=3, scale_up_step=3, scale_down_step=3)],
        quotas={"master": 0})
    comp.add_dag(DAG("one", [Task(f"a{i}", kind="python") for i in range(30)]))
    for _ in range(40):
        comp.tick()
        if (comp.scheduler.dag_done("one", probe=False)
                and asc.replicas("default") == 0):
            break
    assert comp.scheduler.dag_success("one")
    assert asc.replicas("default") == 0            # fleet fully retired
    assert plane.dispatcher.queue_depths() == {}   # queue tombstoned
    # cold start: a new backlog resurrects the fleet with fresh pods
    comp.add_dag(DAG("two", [Task(f"b{i}", kind="python") for i in range(30)]))
    for _ in range(40):
        comp.tick()
        if comp.scheduler.dag_done("two", probe=False):
            break
    assert comp.scheduler.dag_success("two")
    ups = [e for e in asc.events if e[2] == "scale_up"]
    downs = [e for e in asc.events if e[2] == "scale_down"]
    assert len(ups) >= 4 and len(downs) >= 3       # two generations of pods


def test_no_task_lost_or_double_executed_across_scale_down():
    """The acceptance property: an elastic run with mid-backlog scale-down
    events executes every task EXACTLY once — drains commit in-flight work
    and final-ack it, so no lease ever expires into a redelivery."""
    plane = _hybrid_plane()
    counts = Counter()

    def setup(worker):
        worker.register("count",
                        lambda p, _c=counts: {"n": _c.update([p["i"]]) or 1})

    comp = HybridComposer(plane, workers={}, worker_batch=8,
                          worker_setup=setup)
    asc = comp.attach_autoscaler(
        [_policy(max_replicas=8, scale_up_step=8, scale_down_step=1)],
        quotas={"onprem-a": 4, "master": 0}, preferred=("onprem-a",))
    n = 400
    comp.add_dag(DAG("d", [Task(f"t{i}", kind="count", payload={"i": i})
                           for i in range(n)]))
    done_at = None
    for tick in range(1, 80):
        comp.tick()
        if done_at is None and comp.scheduler.dag_done("d", probe=False):
            done_at = tick
        if done_at is not None and asc.replicas("default") == 0:
            break
    assert comp.scheduler.dag_success("d")
    assert len(counts) == n                        # zero lost
    assert all(c == 1 for c in counts.values())    # zero double-executed
    assert comp.broker.stats.get("redelivered", 0) == 0
    assert comp.broker.stats.get("redelivered_nacked", 0) == 0
    assert sum(1 for e in asc.events if e[2] == "scale_down") >= 1
    assert asc.replicas("default") == 0


def test_autoscaled_fleet_drains_within_bound_of_static():
    """Small-scale version of the benchmark gate: the elastic fleet's time to
    drain stays within 1.5x an optimally-sized static fleet."""
    def drain_ticks(autoscaled: bool) -> int:
        plane = _hybrid_plane()
        if autoscaled:
            comp = HybridComposer(plane, workers={}, worker_batch=16)
            comp.attach_autoscaler(
                [_policy(max_replicas=4, scale_up_step=2,
                         target_depth_per_worker=64)],
                quotas={"onprem-a": 2, "master": 0}, preferred=("onprem-a",))
        else:
            comp = HybridComposer(
                plane, workers={"onprem-a": ["s0", "s1"],
                                "cloud-a": ["s2", "s3"]}, worker_batch=16)
        comp.add_dag(DAG("d", [Task(f"t{i}", kind="python")
                               for i in range(800)]))
        for tick in range(1, 200):
            comp.tick()
            if comp.scheduler.dag_done("d", probe=False):
                assert comp.scheduler.dag_success("d", probe=False)
                return tick
        raise AssertionError("backlog never drained")

    static = drain_ticks(False)
    auto = drain_ticks(True)
    assert auto <= 1.5 * static, (auto, static)


def test_reconciler_prunes_pods_lost_to_cluster_death():
    plane = _hybrid_plane()
    comp = HybridComposer(plane, workers={}, worker_batch=8)
    asc = comp.attach_autoscaler(
        [_policy(max_replicas=2, scale_up_step=2)],
        quotas={"onprem-a": 1, "cloud-a": 1, "master": 0},
        preferred=("onprem-a",))
    comp.add_dag(DAG("d", [Task(f"t{i}", kind="python") for i in range(200)]))
    comp.tick()
    assert asc.replicas("default") == 2
    plane.fabric.partition_cluster("cloud-a")
    for _ in range(30):
        comp.tick()
        if comp.scheduler.dag_done("d", probe=False):
            break
    assert comp.scheduler.dag_success("d")
    assert any(e[2] == "lost" and e[4] == "cloud-a" for e in asc.events)
    # the surviving fleet never exceeds what live clusters can host
    assert all(r.cluster != "cloud-a" for r in asc.pods["default"].values())


# ------------------------------------------------------------ retire surface
def test_retire_tombstones_job_records():
    plane = ManagementPlane()
    plane.add_cluster("master", is_master=True)
    plane.add_cluster("c1")
    jid = plane.submit_job("sim", steps=10 ** 9)
    plane.tick(n=2)
    assert plane.job_status(jid)["status"] == "running"
    assert plane.retire_job(jid) is True
    # not failed, not done — GONE: no /jobs keys, no view entries, nothing
    # for recovery or stragglers to resurrect, no leak under elastic churn
    assert plane.job_status(jid) is None
    assert plane.overwatch.handle(
        {"op": "range", "prefix": f"/jobs/{jid}/"})["items"] == {}
    assert plane.dispatcher.placement_of(jid) is None
    assert plane.dispatcher.job_status(jid) is None
    # the agent forgot it too: no more heartbeat telemetry rows for the pod
    plane.tick(n=2)
    assert plane.job_status(jid) is None
    assert plane.agents["c1"].jobs.get(jid) is None
    # idempotent surface: retiring an unknown job is a no-op
    assert plane.retire_job("nope") is False


def test_retire_in_absentia_survives_healed_partition():
    """Retire while the hosting cluster is partitioned (but still leased),
    then heal the partition BEFORE the lease expires: the agent's next
    heartbeat must not resurrect the job — the dispatcher finishes the
    retirement instead of letting a 10^9-step zombie live forever."""
    plane = ManagementPlane()
    plane.add_cluster("master", is_master=True)
    plane.add_cluster("c1")
    jid = plane.submit_job("sim", steps=10 ** 9)
    plane.tick()
    plane.fabric.partition_cluster("c1")
    assert plane.retire_job(jid) is True           # in absentia
    assert plane.job_status(jid) is None
    plane.fabric.heal_cluster("c1")                # before lease expiry
    plane.tick(n=3)
    # the heartbeat's status re-put was intercepted: retire re-sent, key
    # re-tombstoned, agent forgot the job, views stay clean
    assert plane.job_status(jid) is None
    assert plane.agents["c1"].jobs.get(jid) is None
    assert plane.dispatcher.job_status(jid) is None


def test_broadcast_tolerates_partitioned_cluster():
    """An AppSpec re-broadcast (elastic pod churn) must not be hostage to one
    partitioned-but-not-yet-tombstoned cluster."""
    plane = _hybrid_plane()
    comp = HybridComposer(plane, workers={}, worker_batch=8)
    asc = comp.attach_autoscaler(
        [_policy(max_replicas=2, scale_up_step=2)],
        quotas={"onprem-a": 2, "master": 0}, preferred=("onprem-a",))
    plane.fabric.partition_cluster("cloud-a")      # leased, unreachable
    comp.add_dag(DAG("d", [Task(f"t{i}", kind="python") for i in range(60)]))
    for _ in range(30):
        comp.tick()                                # spawns re-broadcast here
        if comp.scheduler.dag_done("d", probe=False):
            break
    assert comp.scheduler.dag_success("d")
    ups = [e for e in asc.events if e[2] == "scale_up"]
    assert ups and all(e[4] == "onprem-a" for e in ups)


def test_spawn_survives_partitioned_spillover_cluster():
    """Preferred tier at quota, spillover target partitioned but still
    leased: the spawn must fail gracefully (and retry later), never crash
    the composer tick."""
    plane = _hybrid_plane()
    comp = HybridComposer(plane, workers={}, worker_batch=8)
    asc = comp.attach_autoscaler(
        [_policy(max_replicas=3, scale_up_step=3)],
        quotas={"onprem-a": 1, "master": 0}, preferred=("onprem-a",))
    plane.fabric.partition_cluster("cloud-a")
    comp.add_dag(DAG("d", [Task(f"t{i}", kind="python") for i in range(60)]))
    for _ in range(40):
        comp.tick()
        if comp.scheduler.dag_done("d", probe=False):
            break
    assert comp.scheduler.dag_success("d")
    assert any(e[2] == "spawn_failed" for e in asc.events)
    assert all(e[4] != "cloud-a" for e in asc.events if e[2] == "scale_up")
