"""Every assigned (arch x shape) cell must trace + lower on a small mesh with
the same (pod, data, model) axis names as production. (Full 256/512-device
compiles run in launch/dryrun.py; artifacts land in artifacts/dryrun/.)"""
import jax
import pytest

from repro.configs import base as configs
from repro.configs.shapes import SHAPES, cell_is_runnable
from repro.launch.steps import CellOptions, build_cell

CELLS = [(a, s) for a in configs.names() for s in SHAPES
         if not cell_is_runnable(configs.get(a), s)]
SKIPS = [(a, s) for a in configs.names() for s in SHAPES
         if cell_is_runnable(configs.get(a), s)]


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1, 1), ("pod", "data", "model"))


@pytest.mark.parametrize("arch,shape", CELLS)
def test_cell_lowers(arch, shape, mesh):
    cell = build_cell(arch, shape, mesh, CellOptions(num_microbatches=2))
    lowered = cell.lower()
    assert "HloModule" in lowered.as_text()[:200] or lowered is not None


def test_skip_set_matches_design():
    # exactly the 7 pure-full-attention archs skip long_500k
    assert sorted(a for a, s in SKIPS) == sorted([
        "qwen3-32b", "phi4-mini-3.8b", "qwen3-0.6b", "deepseek-moe-16b",
        "qwen3-moe-235b-a22b", "whisper-medium", "llama-3.2-vision-90b"])
    assert {s for _, s in SKIPS} == {"long_500k"}
    assert len(CELLS) + len(SKIPS) == 40


def test_dryrun_artifacts_complete():
    """If the production dry-run ran, both meshes must cover all 33 cells."""
    from pathlib import Path
    art = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"
    if not art.exists():
        pytest.skip("production dry-run not executed in this checkout")
    for mesh_kind in ("single", "multi"):
        files = {p.stem for p in (art / mesh_kind).glob("*.json")
                 if "__" in p.stem and not p.stem.count("__") > 1}
        want = {f"{a}__{s}" for a, s in CELLS}
        missing = want - files
        assert not missing, f"{mesh_kind} missing {sorted(missing)[:5]}..."
